//! Convolution code generation (the loop skeletons of Fig. 3).
//!
//! **Kloop** (maps resident per tile): per map tile, stream kernel
//! groups through the double-buffered weight buffers; inside, Y and X
//! loops walk windows whose kh×segment MAC traces accumulate in the
//! vMACs, with VMOV-staged biases and residual bypass values applied on
//! writeback.
//!
//! **Mloop** (kernels streamed once, maps fully resident): available
//! when every map strip fits its own MBuf bank simultaneously
//! (`n_tiles ≤ mbuf_banks`) and the conv has no fused bypass. All
//! strips are staged in the prologue; the kernel-group loop then walks
//! the tiles *inside* each group iteration, so the kernel stream is
//! read exactly once instead of once per tile — the §6.2 rearrangement
//! that trades map residency for kernel-traffic elimination. The
//! schedule tuner ([`crate::compiler::cost`]) picks between the two per
//! layer.
//!
//! **Mloop-rotation** (banked rotation, ISSUE 5): extends the kernel-
//! traffic elimination to layers with *more* tiles than MBuf banks.
//! Kernel **sets** — as many groups as fit one WBuf region
//! ([`crate::compiler::cost::rot_sets`]) — are loaded once per *pass*;
//! inside a pass the tile walk streams each map strip through a
//! rotating bank: at global step `s = pass·n_tiles + t` the strip of
//! tile `t` computes from bank `s % mbuf_banks` while the strip needed
//! `mbuf_banks − 1` steps later prefetches into the bank just vacated.
//! The bank phase `(pass·n_tiles) % mbuf_banks` is static per pass
//! (passes are unrolled into blocks), so the rotation needs no runtime
//! modulo. The DMA-completion guard before each strip's first window is
//! the §5.2 scoreboard itself: the prefetch LD is issued *before* the
//! tile's MACs, so every MAC observes the fill's generation at dispatch
//! and the CU stalls until the strip has landed; conversely the LD
//! issue stage is interlocked on queued readers of the bank it
//! overwrites, so a fill can never land under a not-yet-consumed
//! window. Kernels are read exactly once for any tile count; the price
//! is one map-strip pass per kernel set.
//!
//! The emitters deliberately share the window walk and the WBuf
//! prefetch protocol *textually* (the Y/X loop bodies and the
//! Muli/Add/Ld/Mov toggle sequence are the same instructions): the
//! `counted_loop` `FnOnce` nesting makes a parameterized shared helper
//! more tangled than the duplication it removes. Any edit to one
//! skeleton's window walk or prefetch must be mirrored in the others —
//! `tests/sim_equivalence.rs`, `tests/compile_sim.rs` and
//! `tests/rotation.rs` pin all three against the per-cycle core and the
//! reference implementation.

use super::emit::*;
use crate::compiler::balance::{StreamClass, UnitAllocator};
use crate::compiler::decide::ConvPlan;
use crate::compiler::layout::Canvas;
use crate::compiler::tile::{map_tiles, MapTile};
use crate::compiler::{CompileOptions, LoopOrder};
use crate::arch::SnowflakeConfig;
use crate::isa::instr::{Instr, LdTarget, MacFlags, Program, VmovSel};

pub struct ConvCtx<'a> {
    pub cfg: &'a SnowflakeConfig,
    pub opts: &'a CompileOptions,
    pub d: &'a ConvPlan,
    pub in_cv: Canvas,
    pub out_cv: Canvas,
    pub byp_cv: Option<Canvas>,
    pub weights_addr: usize,
    pub bias_addr: usize,
}

/// Emit the per-CU maps strip loads for one tile into MBuf bank `bank`
/// (split per the layer's tuned schedule). The Kloop/Mloop skeletons
/// pass `tile.bank` (so their emission is unchanged); the banked-
/// rotation skeleton decouples the bank from the tile to rotate strips
/// through the banks with a per-pass phase.
fn emit_maps_loads(
    e: &mut Emitter,
    ctx: &ConvCtx,
    tile: &MapTile,
    bank: usize,
    alloc: &mut UnitAllocator,
) {
    let d = ctx.d;
    let strip_rows = tile.in_rows(d.kh, d.stride) + crate::compiler::decide::CONV_SPILL_ROWS;
    let strip_words = strip_rows * ctx.in_cv.row_words();
    let bank_base = bank * ctx.cfg.mbuf_bank_words();
    let split = d.split.max(1).min(strip_words.div_ceil(64));
    for cu in 0..ctx.cfg.n_cus {
        // First canvas row of this CU's strip: output row oy maps to
        // canvas row oy*stride + (mp - pad).
        let cy0 = tile.cu_oy0(cu) * d.stride + (ctx.in_cv.mp - d.pad);
        let mem0 = ctx.in_cv.raw_row(cy0);
        let piece = strip_words.div_ceil(split);
        let mut off = 0usize;
        while off < strip_words {
            let len = piece.min(strip_words - off);
            let unit = alloc.unit_for(StreamClass::Maps, len);
            e.movi(R_LDTMP, (bank_base + off) as i64);
            e.movi(R_T0, (mem0 + off) as i64);
            e.movi(R_T1, len as i64);
            e.c(
                Instr::Ld {
                    target: LdTarget::MBuf { cu: cu as u8, bank: bank as u8 },
                    broadcast: false,
                    unit,
                    rd: R_LDTMP,
                    rs1: R_T0,
                    rs2: R_T1,
                },
                &format!("maps strip tile{} cu{}", tile.index, cu),
            );
            off += len;
        }
    }
}

/// Emit the per-CU bypass strip loads for one tile (into BBuf above the
/// bias array).
fn emit_bypass_loads(e: &mut Emitter, ctx: &ConvCtx, tile: &MapTile, alloc: &mut UnitAllocator) {
    let d = ctx.d;
    let byp = ctx.byp_cv.expect("bypass canvas");
    let bias_sz = d.k_groups * 4;
    let words = tile.rows_per_cu * byp.row_words();
    assert!(
        bias_sz + words <= ctx.cfg.bbuf_words(),
        "bypass strip ({} words) + biases ({}) exceed BBuf",
        words,
        bias_sz
    );
    for cu in 0..ctx.cfg.n_cus {
        let cy0 = tile.cu_oy0(cu) + byp.mp;
        let mem0 = byp.raw_row(cy0);
        let unit = alloc.unit_for(StreamClass::Bias, words);
        e.movi(R_LDTMP, bias_sz as i64);
        e.movi(R_T0, mem0 as i64);
        e.movi(R_T1, words as i64);
        e.c(
            Instr::Ld {
                target: LdTarget::BBuf { cu: cu as u8 },
                broadcast: false,
                unit,
                rd: R_LDTMP,
                rs1: R_T0,
                rs2: R_T1,
            },
            &format!("bypass strip tile{} cu{}", tile.index, cu),
        );
    }
}

/// Emit the 4 kernel loads of one group. Target WBuf region base comes
/// from register `buf_reg` (compute-time value), stream address from
/// `R_LDTMP` (caller sets it to the group base), advancing by `R_KW`.
fn emit_kernel_group_loads(e: &mut Emitter, ctx: &ConvCtx, buf_reg: u8, alloc: &mut UnitAllocator) {
    let d = ctx.d;
    e.movi(R_T1, d.kernel_words as i64);
    for v in 0..ctx.cfg.vmacs_per_cu {
        let unit = alloc.unit_for(StreamClass::Weights, d.kernel_words);
        e.c(
            Instr::Ld {
                target: LdTarget::WBuf { cu: 0, vmac: v as u8 },
                broadcast: true,
                unit,
                rd: buf_reg,
                rs1: R_LDTMP,
                rs2: R_T1,
            },
            &format!("kernels vmac{v}"),
        );
        if v + 1 < ctx.cfg.vmacs_per_cu {
            e.e(Instr::Add { rd: R_LDTMP, rs1: R_LDTMP, rs2: R_KW });
        }
    }
}

/// Emit the inner window MAC sequence (kh rows × segments).
fn emit_window(e: &mut Emitter, ctx: &ConvCtx) {
    let d = ctx.d;
    if d.has_bypass {
        e.e(Instr::Vmov { sel: VmovSel::Bypass, rs1: R_BYP, wide: false });
    }
    e.e(Instr::Add { rd: R_MTRACE, rs1: R_MWIN, rs2: 0 });
    e.e(Instr::Add { rd: R_WTRACE, rs1: R_WREG, rs2: 0 });
    let n_segs = d.geom.segs.len();
    for fy in 0..d.kh {
        for (si, &seg) in d.geom.segs.iter().enumerate() {
            let first = fy == 0 && si == 0;
            let last = fy == d.kh - 1 && si == n_segs - 1;
            let flags = MacFlags {
                reset: first,
                writeback: last,
                relu: last && d.relu,
                bypass: last && d.has_bypass,
            };
            e.e(Instr::Mac {
                coop: true,
                rd: R_OUT,
                rs1: R_MTRACE,
                rs2: R_WTRACE,
                len: (seg / 16) as u8,
                flags,
            });
            if !last {
                e.e(Instr::Addi { rd: R_MTRACE, rs1: R_MTRACE, imm: seg as i16 });
                e.e(Instr::Addi { rd: R_WTRACE, rs1: R_WTRACE, imm: seg as i16 });
            }
        }
        if fy + 1 < d.kh {
            e.e(Instr::Add { rd: R_MTRACE, rs1: R_MTRACE, rs2: R_ROWFIX });
        }
    }
}

/// Emit a full convolution layer with the skeleton the schedule chose.
pub fn emit_conv(ctx: &ConvCtx, alloc: &mut UnitAllocator) -> Vec<Program> {
    match ctx.d.order {
        LoopOrder::Kloop => emit_conv_kloop(ctx, alloc),
        LoopOrder::Mloop => emit_conv_mloop(ctx, alloc),
        LoopOrder::MloopRot => emit_conv_mloop_rot(ctx, alloc),
    }
}

/// Shared prologue: pipeline constants plus the broadcast bias-array
/// load. Maps staging differs per skeleton and is emitted by callers.
fn emit_conv_prologue(e: &mut Emitter, ctx: &ConvCtx, alloc: &mut UnitAllocator) {
    let d = ctx.d;
    let row_words_in = ctx.in_cv.row_words() as i64;
    let row_words_out = ctx.out_cv.row_words() as i64;
    e.movi(R_ROWW_IN, row_words_in);
    e.movi(R_XADV, (d.stride * d.c_pad_in) as i64);
    e.movi(R_ROWW_OUT, row_words_out);
    e.movi(R_CPO, d.c_pad_out as i64);
    e.movi(R_KW, d.kernel_words as i64);
    e.movi(R_YADV, (d.stride) as i64 * row_words_in);
    e.movi(R_ROWFIX, row_words_in - d.geom.row_read as i64);
    e.movi(28, 1); // vmac output stride: adjacent channels
    if d.has_bypass {
        e.movi(R_MISC, ctx.byp_cv.unwrap().row_words() as i64);
    }
    if d.dbuf_w {
        e.movi(R_REGION, ctx.cfg.wbuf_region_words() as i64);
    }
    // Bias array -> BBuf[0..] (broadcast).
    let words = d.k_groups * 4;
    let unit = alloc.unit_for(StreamClass::Bias, words);
    e.movi(R_LDTMP, 0);
    e.movi(R_T0, ctx.bias_addr as i64);
    e.movi(R_T1, words as i64);
    e.c(
        Instr::Ld {
            target: LdTarget::BBuf { cu: 0 },
            broadcast: true,
            unit,
            rd: R_LDTMP,
            rs1: R_T0,
            rs2: R_T1,
        },
        "bias array",
    );
}

/// The Kloop skeleton: a prologue block plus one block per map tile,
/// kernel groups streamed through the double-buffered WBuf per tile.
fn emit_conv_kloop(ctx: &ConvCtx, alloc: &mut UnitAllocator) -> Vec<Program> {
    let cfg = ctx.cfg;
    let d = ctx.d;
    let tiles = map_tiles(d.h_out, d.rows_per_cu, cfg);
    let region_words = cfg.wbuf_region_words();
    let mut blocks = Vec::new();

    // ------------------------- prologue -------------------------------
    let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
    let row_words_out = ctx.out_cv.row_words() as i64;
    emit_conv_prologue(&mut e, ctx, alloc);
    // Maps strips for tile 0.
    emit_maps_loads(&mut e, ctx, &tiles[0], tiles[0].bank, alloc);
    blocks.push(e.prog);

    // ------------------------- tiles ----------------------------------
    for (t, tile) in tiles.iter().enumerate() {
        let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
        // Prefetch next tile's maps into the other bank.
        if t + 1 < tiles.len() {
            emit_maps_loads(&mut e, ctx, &tiles[t + 1], tiles[t + 1].bank, alloc);
        }
        if d.has_bypass {
            emit_bypass_loads(&mut e, ctx, tile, alloc);
        }
        // Kernel group 0 of this tile.
        let parity = if d.dbuf_w { (t * d.k_groups) % 2 } else { 0 };
        e.movi(R_WREG, (parity * region_words) as i64);
        e.movi(R_LDTMP, ctx.weights_addr as i64);
        emit_kernel_group_loads(&mut e, ctx, R_WREG, alloc);
        e.movi(R_KMEM, (ctx.weights_addr + 4 * d.kernel_words) as i64);
        e.movi(R_OUTBASE, ctx.out_cv.addr_u(0, tile.oy0, 0) as i64);
        e.movi(31, tile.rows_per_cu as i64 * row_words_out); // per-CU row offset
        e.movi(R_BIAS, 0);

        let bank_base = (tile.bank * cfg.mbuf_bank_words()) as i64;
        let col_off = ((ctx.in_cv.mp - d.pad) * d.c_pad_in) as i64;
        let byp0_off = ctx
            .byp_cv
            .map(|b| (d.k_groups * 4 + b.mp * d.c_pad_out) as i64)
            .unwrap_or(0);

        e.counted_loop(
            R_KC,
            R_KL,
            d.k_groups,
            |e| {
                e.e(Instr::Vmov { sel: VmovSel::Bias, rs1: R_BIAS, wide: false });
                e.movi(R_MROW, bank_base);
                e.e(Instr::Add { rd: R_T1, rs1: R_OUTBASE, rs2: R_BIAS });
                if d.has_bypass {
                    e.addi(R_T0, R_BIAS, byp0_off);
                }
                e.counted_loop(
                    R_YC,
                    R_YL,
                    tile.rows_per_cu,
                    |e| {
                        e.addi(R_MWIN, R_MROW, col_off);
                        e.e(Instr::Add { rd: R_OUT, rs1: R_T1, rs2: 0 });
                        if d.has_bypass {
                            e.e(Instr::Add { rd: R_BYP, rs1: R_T0, rs2: 0 });
                        }
                        e.counted_loop(
                            R_XC,
                            R_XL,
                            d.w_out,
                            |e| emit_window(e, ctx),
                            |e, _| {
                                e.e(Instr::Add { rd: R_MWIN, rs1: R_MWIN, rs2: R_XADV });
                                e.e(Instr::Add { rd: R_OUT, rs1: R_OUT, rs2: R_CPO });
                                if d.has_bypass {
                                    e.e(Instr::Add { rd: R_BYP, rs1: R_BYP, rs2: R_CPO });
                                }
                            },
                        );
                    },
                    |e, _| {
                        e.e(Instr::Add { rd: R_MROW, rs1: R_MROW, rs2: R_YADV });
                        e.e(Instr::Add { rd: R_T1, rs1: R_T1, rs2: R_ROWW_OUT });
                        if d.has_bypass {
                            e.e(Instr::Add { rd: R_T0, rs1: R_T0, rs2: R_MISC });
                        }
                    },
                );
                // Prefetch the next kernel group (dummy on the last
                // iteration; region interlock keeps reloads safe).
                if d.dbuf_w {
                    e.e(Instr::Muli { rd: R_NOP, rs1: R_WREG, imm: -1 });
                    e.e(Instr::Add { rd: R_T0, rs1: R_REGION, rs2: R_NOP });
                } else {
                    e.e(Instr::Add { rd: R_T0, rs1: 0, rs2: 0 });
                }
                e.e(Instr::Add { rd: R_LDTMP, rs1: R_KMEM, rs2: 0 });
                emit_kernel_group_loads(e, ctx, R_T0, alloc);
                e.e(Instr::Mov { rd: R_NOP, rs1: R_KW, sh: 2 });
                e.e(Instr::Add { rd: R_KMEM, rs1: R_KMEM, rs2: R_NOP });
                if d.dbuf_w {
                    e.e(Instr::Add { rd: R_WREG, rs1: R_T0, rs2: 0 });
                }
            },
            |e, _| {
                e.e(Instr::Addi { rd: R_BIAS, rs1: R_BIAS, imm: 4 });
            },
        );
        blocks.push(e.prog);
    }
    blocks
}

/// The Mloop skeleton: every map strip staged once (each tile in its
/// own MBuf bank), then a single kernel-group loop whose body walks the
/// tiles — the kernel stream is read exactly once. Requires
/// `n_tiles <= mbuf_banks` and no fused bypass ([`crate::compiler::cost::mloop_viable`]);
/// `decide` guarantees both before selecting this skeleton.
fn emit_conv_mloop(ctx: &ConvCtx, alloc: &mut UnitAllocator) -> Vec<Program> {
    let cfg = ctx.cfg;
    let d = ctx.d;
    debug_assert!(!d.has_bypass, "Mloop skeleton cannot stage bypass strips");
    let tiles = map_tiles(d.h_out, d.rows_per_cu, cfg);
    debug_assert!(tiles.len() <= cfg.mbuf_banks, "Mloop needs every strip resident");
    let row_words_out = ctx.out_cv.row_words() as i64;
    let mut blocks = Vec::new();

    // ---------------- prologue: constants + all map strips ------------
    let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
    emit_conv_prologue(&mut e, ctx, alloc);
    for tile in &tiles {
        emit_maps_loads(&mut e, ctx, tile, tile.bank, alloc);
    }
    blocks.push(e.prog);

    // ---------------- the kernel-group loop ---------------------------
    let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
    // Kernel group 0 into region 0; the in-loop prefetch then streams
    // groups 1..=k_groups (the last being the dummy prefetch group).
    e.movi(R_WREG, 0);
    e.movi(R_LDTMP, ctx.weights_addr as i64);
    emit_kernel_group_loads(&mut e, ctx, R_WREG, alloc);
    e.movi(R_KMEM, (ctx.weights_addr + 4 * d.kernel_words) as i64);
    e.movi(R_BIAS, 0);
    let col_off = ((ctx.in_cv.mp - d.pad) * d.c_pad_in) as i64;

    e.counted_loop(
        R_KC,
        R_KL,
        d.k_groups,
        |e| {
            e.e(Instr::Vmov { sel: VmovSel::Bias, rs1: R_BIAS, wide: false });
            for tile in &tiles {
                let bank_base = (tile.bank * cfg.mbuf_bank_words()) as i64;
                e.movi(R_MROW, bank_base);
                e.movi(R_OUTBASE, ctx.out_cv.addr_u(0, tile.oy0, 0) as i64);
                e.movi(31, tile.rows_per_cu as i64 * row_words_out); // per-CU row offset
                e.e(Instr::Add { rd: R_T1, rs1: R_OUTBASE, rs2: R_BIAS });
                e.counted_loop(
                    R_YC,
                    R_YL,
                    tile.rows_per_cu,
                    |e| {
                        e.addi(R_MWIN, R_MROW, col_off);
                        e.e(Instr::Add { rd: R_OUT, rs1: R_T1, rs2: 0 });
                        e.counted_loop(
                            R_XC,
                            R_XL,
                            d.w_out,
                            |e| emit_window(e, ctx),
                            |e, _| {
                                e.e(Instr::Add { rd: R_MWIN, rs1: R_MWIN, rs2: R_XADV });
                                e.e(Instr::Add { rd: R_OUT, rs1: R_OUT, rs2: R_CPO });
                            },
                        );
                    },
                    |e, _| {
                        e.e(Instr::Add { rd: R_MROW, rs1: R_MROW, rs2: R_YADV });
                        e.e(Instr::Add { rd: R_T1, rs1: R_T1, rs2: R_ROWW_OUT });
                    },
                );
            }
            // Prefetch the next kernel group into the other WBuf region
            // (dummy on the last iteration; the region interlock keeps
            // reloads behind pending readers).
            if d.dbuf_w {
                e.e(Instr::Muli { rd: R_NOP, rs1: R_WREG, imm: -1 });
                e.e(Instr::Add { rd: R_T0, rs1: R_REGION, rs2: R_NOP });
            } else {
                e.e(Instr::Add { rd: R_T0, rs1: 0, rs2: 0 });
            }
            e.e(Instr::Add { rd: R_LDTMP, rs1: R_KMEM, rs2: 0 });
            emit_kernel_group_loads(e, ctx, R_T0, alloc);
            e.e(Instr::Mov { rd: R_NOP, rs1: R_KW, sh: 2 });
            e.e(Instr::Add { rd: R_KMEM, rs1: R_KMEM, rs2: R_NOP });
            if d.dbuf_w {
                e.e(Instr::Add { rd: R_WREG, rs1: R_T0, rs2: 0 });
            }
        },
        |e, _| {
            e.e(Instr::Addi { rd: R_BIAS, rs1: R_BIAS, imm: 4 });
        },
    );
    blocks.push(e.prog);
    blocks
}

/// The banked-rotation Mloop skeleton: one block per kernel-set *pass*.
/// Each pass loads its set — [`cost::rot_sets`] groups, each group's 4
/// kernels at `region_base + g·kernel_words` of every vMAC WBuf — with
/// a counted load loop, then walks the tiles. At global step
/// `s = pass·n_tiles + t` the strip of tile `t` is resident in bank
/// `s % mbuf_banks`; the strip needed `mbuf_banks − 1` steps later
/// (tile `(t + mbuf_banks − 1) % n_tiles`, same data every pass)
/// prefetches into bank `(s + mbuf_banks − 1) % mbuf_banks` — the bank
/// the previous step just vacated. All banks are static per (pass,
/// tile) because passes are unrolled, so the phase needs no runtime
/// modulo; the final `mbuf_banks − 1` steps of the last pass emit no
/// prefetch, keeping map traffic at exactly `passes × maps_once`.
///
/// Synchronization is entirely the §5.2 scoreboard/interlock protocol
/// shared with the other skeletons: a strip prefetch LD stalls at issue
/// while queued MACs still reference its target bank (it overwrites the
/// vacated strip only after every reader consumed it), and the tile's
/// MACs — dispatched *after* the LD that staged their strip — observe
/// its fill generation and wait on the CU until the DMA lands. Kernel
/// sets alternate WBuf regions across passes (`dbuf_w` guarantees a set
/// fits one region, never straddling the region scoreboard), so a set
/// load streams while the previous pass's tail still computes.
fn emit_conv_mloop_rot(ctx: &ConvCtx, alloc: &mut UnitAllocator) -> Vec<Program> {
    let cfg = ctx.cfg;
    let d = ctx.d;
    debug_assert!(!d.has_bypass, "Mloop-rotation skeleton cannot stage bypass strips");
    debug_assert!(d.dbuf_w, "Mloop-rotation needs the kernel group inside a WBuf region");
    debug_assert!(cfg.mbuf_banks >= 2, "Mloop-rotation needs banks to rotate through");
    let tiles = map_tiles(d.h_out, d.rows_per_cu, cfg);
    let n_tiles = tiles.len();
    let banks = cfg.mbuf_banks;
    let region_words = cfg.wbuf_region_words();
    let (groups_per_set, passes) =
        crate::compiler::cost::rot_sets(d.kernel_words, d.k_groups, cfg);
    let total_steps = passes * n_tiles;
    let row_words_out = ctx.out_cv.row_words() as i64;
    let col_off = ((ctx.in_cv.mp - d.pad) * d.c_pad_in) as i64;
    let mut blocks = Vec::new();

    // ---------------- prologue: constants + lead strips ---------------
    // Stage the strips of global steps 0..banks−1 (the rotation's
    // prefetch distance); every later strip is prefetched from inside
    // the tile walk, one step ahead per vacated bank.
    let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
    emit_conv_prologue(&mut e, ctx, alloc);
    for s in 0..(banks - 1).min(total_steps) {
        emit_maps_loads(&mut e, ctx, &tiles[s % n_tiles], s % banks, alloc);
    }
    blocks.push(e.prog);

    // ---------------- one block per kernel-set pass --------------------
    for p in 0..passes {
        let set_base = p * groups_per_set;
        let set_groups = groups_per_set.min(d.k_groups - set_base);
        // Alternate WBuf regions across passes so a set load can stream
        // under the previous pass's tail compute; a single-set layer
        // keeps everything in region 0.
        let region_base = if passes > 1 { (p % 2) * region_words } else { 0 };
        let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);

        // Kernel set p: `set_groups` groups, group g of the set landing
        // at region_base + g·kernel_words in each vMAC's WBuf.
        e.movi(R_WREG, region_base as i64);
        e.movi(R_KMEM, (ctx.weights_addr + set_base * 4 * d.kernel_words) as i64);
        e.counted_loop(
            R_XC,
            R_XL,
            set_groups,
            |e| {
                e.e(Instr::Add { rd: R_LDTMP, rs1: R_KMEM, rs2: 0 });
                emit_kernel_group_loads(e, ctx, R_WREG, alloc);
                e.e(Instr::Mov { rd: R_NOP, rs1: R_KW, sh: 2 });
                e.e(Instr::Add { rd: R_KMEM, rs1: R_KMEM, rs2: R_NOP });
            },
            |e, _| {
                e.e(Instr::Add { rd: R_WREG, rs1: R_WREG, rs2: R_KW });
            },
        );

        // The tile walk: compute step s from bank s % banks, prefetch
        // step s + banks − 1 into the bank just vacated.
        for (t, tile) in tiles.iter().enumerate() {
            let s = p * n_tiles + t;
            let pf = s + banks - 1;
            if pf < total_steps {
                emit_maps_loads(&mut e, ctx, &tiles[pf % n_tiles], pf % banks, alloc);
            }
            let bank_base = ((s % banks) * cfg.mbuf_bank_words()) as i64;
            e.movi(R_OUTBASE, ctx.out_cv.addr_u(0, tile.oy0, 0) as i64);
            e.movi(31, tile.rows_per_cu as i64 * row_words_out); // per-CU row offset
            e.movi(R_BIAS, (set_base * 4) as i64);
            e.movi(R_WREG, region_base as i64);
            e.counted_loop(
                R_KC,
                R_KL,
                set_groups,
                |e| {
                    e.e(Instr::Vmov { sel: VmovSel::Bias, rs1: R_BIAS, wide: false });
                    e.movi(R_MROW, bank_base);
                    e.e(Instr::Add { rd: R_T1, rs1: R_OUTBASE, rs2: R_BIAS });
                    e.counted_loop(
                        R_YC,
                        R_YL,
                        tile.rows_per_cu,
                        |e| {
                            e.addi(R_MWIN, R_MROW, col_off);
                            e.e(Instr::Add { rd: R_OUT, rs1: R_T1, rs2: 0 });
                            e.counted_loop(
                                R_XC,
                                R_XL,
                                d.w_out,
                                |e| emit_window(e, ctx),
                                |e, _| {
                                    e.e(Instr::Add { rd: R_MWIN, rs1: R_MWIN, rs2: R_XADV });
                                    e.e(Instr::Add { rd: R_OUT, rs1: R_OUT, rs2: R_CPO });
                                },
                            );
                        },
                        |e, _| {
                            e.e(Instr::Add { rd: R_MROW, rs1: R_MROW, rs2: R_YADV });
                            e.e(Instr::Add { rd: R_T1, rs1: R_T1, rs2: R_ROWW_OUT });
                        },
                    );
                },
                |e, _| {
                    e.e(Instr::Addi { rd: R_BIAS, rs1: R_BIAS, imm: 4 });
                    e.e(Instr::Add { rd: R_WREG, rs1: R_WREG, rs2: R_KW });
                },
            );
        }
        blocks.push(e.prog);
    }
    blocks
}
