//! Fully-connected code generation.
//!
//! FC is the paper's uniform-trace showcase: a 1×1-spatial COOP conv
//! whose single window is one long dot product, chunk-streamed through
//! the double-buffered weight buffers because a whole kernel row exceeds
//! them. 16 kernels are distributed across the machine per iteration —
//! 4 per-CU weight buffers × 4 CUs = the paper's "16 weight LDs in a 4
//! CU system" — with the per-CU output stride (r31 = 4 channels)
//! scattering results. Inherently bandwidth-bound (§2), excluded from
//! the paper's reported times; compiled and measured here regardless.

use super::emit::*;
use crate::arch::SnowflakeConfig;
use crate::compiler::balance::{StreamClass, UnitAllocator};
use crate::compiler::decide::FcPlan;
use crate::compiler::layout::Canvas;
use crate::compiler::CompileOptions;
use crate::isa::instr::{Instr, LdTarget, MacFlags, Program, VmovSel};

pub struct FcCtx<'a> {
    pub cfg: &'a SnowflakeConfig,
    pub opts: &'a CompileOptions,
    pub in_cv: Canvas,
    pub out_cv: Canvas,
    pub weights_addr: usize,
    pub bias_addr: usize,
}

/// Emit an FC layer: prologue + kernel-group loop.
pub fn emit_fc(ctx: &FcCtx, d: &FcPlan, alloc: &mut UnitAllocator) -> Vec<Program> {
    let cfg = ctx.cfg;
    let feat: usize = d.chunks.iter().sum();
    let kernel_words = feat;
    let group_words = 16 * kernel_words;
    let region_words = cfg.wbuf_region_words();
    let mut blocks = Vec::new();

    let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
    // Input feature vector -> MBuf bank 0 (broadcast; the canvas is
    // contiguous for 1x1/flattenable inputs).
    {
        let unit = alloc.unit_for(StreamClass::Maps, feat);
        e.movi(R_LDTMP, 0);
        e.movi(R_T0, ctx.in_cv.base as i64);
        e.movi(R_T1, feat as i64);
        e.e(Instr::Ld {
            target: LdTarget::MBuf { cu: 0, bank: 0 },
            broadcast: true,
            unit,
            rd: R_LDTMP,
            rs1: R_T0,
            rs2: R_T1,
        });
    }
    // Per-CU bias slices: deploy arranges bias as [cu][group][4].
    {
        let slice = d.k_groups * 4;
        for cu in 0..cfg.n_cus {
            let unit = alloc.unit_for(StreamClass::Bias, slice);
            e.movi(R_LDTMP, 0);
            e.movi(R_T0, (ctx.bias_addr + cu * slice) as i64);
            e.movi(R_T1, slice as i64);
            e.e(Instr::Ld {
                target: LdTarget::BBuf { cu: cu as u8 },
                broadcast: false,
                unit,
                rd: R_LDTMP,
                rs1: R_T0,
                rs2: R_T1,
            });
        }
    }
    e.movi(28, 1); // vmac stride: adjacent channels
    e.movi(31, 4); // CU stride: 4 channels
    e.movi(R_KW, kernel_words as i64);
    e.movi(R_REGION, region_words as i64);
    e.movi(R_KMEM, ctx.weights_addr as i64);
    e.movi(R_WREG, 0);
    e.movi(R_BIAS, 0);
    e.movi(R_OUT, ctx.out_cv.addr_u(0, 0, 0) as i64);
    blocks.push(e.prog);

    // Kernel-group loop: weights for group kg live at
    // weights_addr + kg*group_words, arranged [chunk][cu][vmac][chunk_words].
    let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
    e.counted_loop(
        R_KC,
        R_KL,
        d.k_groups,
        |e| {
            e.e(Instr::Vmov { sel: VmovSel::Bias, rs1: R_BIAS, wide: false });
            // Chunks: load chunk j (region j%2), MAC it; the region
            // interlock orders reloads behind pending readers.
            let mut m_off = 0usize;
            let mut w_off = 0usize; // offset within the group's DRAM image
            e.movi(R_T1, 0); // placeholder; set per chunk below
            for (j, &chunk) in d.chunks.iter().enumerate() {
                let region = (j % 2) * region_words;
                // 16 per-CU kernel-chunk loads.
                e.movi(R_T1, chunk as i64);
                for cu in 0..cfg.n_cus {
                    for v in 0..cfg.vmacs_per_cu {
                        let unit = alloc.unit_for(StreamClass::Weights, chunk);
                        e.addi(
                            R_LDTMP,
                            R_KMEM,
                            (w_off + (cu * cfg.vmacs_per_cu + v) * chunk) as i64,
                        );
                        e.movi(R_T0, region as i64);
                        e.e(Instr::Ld {
                            target: LdTarget::WBuf { cu: cu as u8, vmac: v as u8 },
                            broadcast: false,
                            unit,
                            rd: R_T0,
                            rs1: R_LDTMP,
                            rs2: R_T1,
                        });
                    }
                }
                // MAC over this chunk.
                e.movi(R_MTRACE, m_off as i64);
                e.movi(R_WTRACE, region as i64);
                let last = j + 1 == d.chunks.len();
                e.e(Instr::Mac {
                    coop: true,
                    rd: R_OUT,
                    rs1: R_MTRACE,
                    rs2: R_WTRACE,
                    len: (chunk / 16) as u8,
                    flags: MacFlags {
                        reset: j == 0,
                        writeback: last,
                        relu: last && d.relu,
                        bypass: false,
                    },
                });
                m_off += chunk;
                w_off += 16 * chunk;
            }
            // Advance to the next group.
            e.addi(R_KMEM, R_KMEM, group_words as i64);
        },
        |e, _| {
            e.e(Instr::Addi { rd: R_BIAS, rs1: R_BIAS, imm: 4 });
            e.e(Instr::Addi { rd: R_OUT, rs1: R_OUT, imm: 16 });
        },
    );
    blocks.push(e.prog);
    blocks
}
