//! Pooling code generation.
//!
//! **Max pooling** drives the pool unit's MAX instruction: lanes are 16
//! output columns (register lane stride = stride × c_pad over the
//! interleaved canvas), one MAX per window tap, writeback of the
//! retained vector with a partial lane count on the ragged last group.
//!
//! **Average pooling** follows §2's prescription — "implemented as a
//! CONV with a single weight value of inverse of window size" — lowered
//! depthwise onto INDP MACs with per-vMAC *diagonal* weight blocks: lane
//! `l` of vMAC `v` holds 1/(kh·kw) at trace step `v·16+l` and zero
//! elsewhere, so one 64-step trace accumulates 64 channel means.

use super::emit::*;
use crate::arch::SnowflakeConfig;
use crate::compiler::balance::{StreamClass, UnitAllocator};
use crate::compiler::decide::{AvgPlan, PoolPlan};
use crate::compiler::layout::Canvas;
use crate::compiler::tile::{map_tiles, MapTile};
use crate::compiler::CompileOptions;
use crate::isa::instr::{Instr, LdTarget, MacFlags, Program, VmovSel};

pub struct PoolCtx<'a> {
    pub cfg: &'a SnowflakeConfig,
    pub opts: &'a CompileOptions,
    pub in_cv: Canvas,
    pub out_cv: Canvas,
}

fn emit_pool_maps_loads(
    e: &mut Emitter,
    ctx: &PoolCtx,
    d: &PoolPlan,
    tile: &MapTile,
    alloc: &mut UnitAllocator,
) {
    // Spill rows: the 16-lane strided read of the last x-group can run
    // into the following canvas rows.
    let strip_rows = tile.in_rows(d.kh, d.stride) + d.spill;
    let strip_words = strip_rows * ctx.in_cv.row_words();
    let bank_base = tile.bank * ctx.cfg.mbuf_bank_words();
    assert!(strip_words <= ctx.cfg.mbuf_bank_words(), "pool strip exceeds MBuf bank");
    let split = alloc.map_split().min(strip_words.div_ceil(64));
    for cu in 0..ctx.cfg.n_cus {
        let cy0 = tile.cu_oy0(cu) * d.stride + (ctx.in_cv.mp - d.pad);
        let mem0 = ctx.in_cv.raw_row(cy0);
        let piece = strip_words.div_ceil(split);
        let mut off = 0usize;
        while off < strip_words {
            let len = piece.min(strip_words - off);
            let unit = alloc.unit_for(StreamClass::Maps, len);
            e.movi(R_LDTMP, (bank_base + off) as i64);
            e.movi(R_T0, (mem0 + off) as i64);
            e.movi(R_T1, len as i64);
            e.e(Instr::Ld {
                target: LdTarget::MBuf { cu: cu as u8, bank: tile.bank as u8 },
                broadcast: false,
                unit,
                rd: R_LDTMP,
                rs1: R_T0,
                rs2: R_T1,
            });
            off += len;
        }
    }
}

/// Emit a max-pool layer: one block per map tile.
pub fn emit_maxpool(ctx: &PoolCtx, d: &PoolPlan, alloc: &mut UnitAllocator) -> Vec<Program> {
    let cfg = ctx.cfg;
    let tiles = map_tiles(d.h_out, d.rows_per_cu, cfg);
    let row_words_in = ctx.in_cv.row_words() as i64;
    let row_words_out = ctx.out_cv.row_words() as i64;
    let mut blocks = Vec::new();

    // Prologue: constants + tile 0 strips.
    let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
    e.movi(R_ROWW_IN, row_words_in);
    e.movi(R_XADV, (d.stride * d.c_pad) as i64); // lane stride register
    e.movi(R_YADV, d.stride as i64 * row_words_in);
    e.movi(R_ROWW_OUT, row_words_out);
    e.movi(28, ctx.out_cv.c_pad as i64); // writeback lane stride: columns
    emit_pool_maps_loads(&mut e, ctx, d, &tiles[0], alloc);
    blocks.push(e.prog);

    for (t, tile) in tiles.iter().enumerate() {
        let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
        if t + 1 < tiles.len() {
            emit_pool_maps_loads(&mut e, ctx, d, &tiles[t + 1], alloc);
        }
        let bank_base = (tile.bank * cfg.mbuf_bank_words()) as i64;
        let col_off = ((ctx.in_cv.mp - d.pad) * d.c_pad) as i64;
        e.movi(R_MROW, bank_base + col_off);
        e.movi(R_OUTBASE, ctx.out_cv.addr_u(0, tile.oy0, 0) as i64);
        e.movi(31, tile.rows_per_cu as i64 * row_words_out);
        e.counted_loop(
            R_YC,
            R_YL,
            tile.rows_per_cu,
            |e| {
                // Channel loop: R_MWIN walks +1 per channel, R_OUT too.
                e.e(Instr::Add { rd: R_MWIN, rs1: R_MROW, rs2: 0 });
                e.e(Instr::Add { rd: R_OUT, rs1: R_OUTBASE, rs2: 0 });
                e.counted_loop(
                    R_XC,
                    R_XL,
                    d.c,
                    |e| {
                        // x-groups unrolled: lanes = output columns.
                        for xg in 0..d.x_groups {
                            let lanes_left = d.w_out - xg * 16;
                            let wb_lanes = if lanes_left >= 16 { 0 } else { lanes_left as u8 };
                            // Tap base for this group.
                            e.addi(
                                R_MTRACE,
                                R_MWIN,
                                (xg * 16 * d.stride * d.c_pad) as i64,
                            );
                            e.addi(R_T1, R_OUT, (xg * 16) as i64 * ctx.out_cv.c_pad as i64);
                            for fy in 0..d.kh {
                                for fx in 0..d.kw {
                                    let first = fy == 0 && fx == 0;
                                    let last = fy == d.kh - 1 && fx == d.kw - 1;
                                    e.e(Instr::Max {
                                        rd: R_T1,
                                        rs1: R_MTRACE,
                                        rs2: R_XADV,
                                        wb_lanes,
                                        flags: MacFlags {
                                            reset: first,
                                            writeback: last,
                                            relu: false,
                                            bypass: false,
                                        },
                                    });
                                    if !last {
                                        if fx + 1 < d.kw {
                                            e.addi(R_MTRACE, R_MTRACE, d.c_pad as i64);
                                        } else {
                                            e.addi(
                                                R_MTRACE,
                                                R_MTRACE,
                                                row_words_in - ((d.kw - 1) * d.c_pad) as i64,
                                            );
                                        }
                                    }
                                }
                            }
                        }
                    },
                    |e, _| {
                        e.e(Instr::Addi { rd: R_MWIN, rs1: R_MWIN, imm: 1 });
                        e.e(Instr::Addi { rd: R_OUT, rs1: R_OUT, imm: 1 });
                    },
                );
            },
            |e, _| {
                e.e(Instr::Add { rd: R_MROW, rs1: R_MROW, rs2: R_YADV });
                e.e(Instr::Add { rd: R_OUTBASE, rs1: R_OUTBASE, rs2: R_ROWW_OUT });
            },
        );
        blocks.push(e.prog);
    }
    blocks
}

pub struct AvgCtx<'a> {
    pub cfg: &'a SnowflakeConfig,
    pub opts: &'a CompileOptions,
    pub in_cv: Canvas,
    pub out_cv: Canvas,
    pub weights_addr: usize,
    pub zero_addr: usize,
}

/// Emit an average-pool layer (depthwise INDP lowering). All CUs
/// compute the same chunks redundantly (r31 = 0) — the layer is tiny.
pub fn emit_avgpool(ctx: &AvgCtx, d: &AvgPlan, alloc: &mut UnitAllocator) -> Vec<Program> {
    let cfg = ctx.cfg;
    let row_words_in = ctx.in_cv.row_words() as i64;
    let mut blocks = Vec::new();

    let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
    // Whole input canvas -> MBuf bank 0 (broadcast) when it fits; the
    // oversized case (e.g. 7x7x2048) gathers per-chunk pieces instead.
    let in_words = ctx.in_cv.words();
    let resident = in_words <= cfg.mbuf_bank_words();
    if resident {
        let unit = alloc.unit_for(StreamClass::Maps, in_words);
        e.movi(R_LDTMP, 0);
        e.movi(R_T0, ctx.in_cv.base as i64);
        e.movi(R_T1, in_words as i64);
        e.e(Instr::Ld {
            target: LdTarget::MBuf { cu: 0, bank: 0 },
            broadcast: true,
            unit,
            rd: R_LDTMP,
            rs1: R_T0,
            rs2: R_T1,
        });
    }
    // Diagonal weight blocks (one per vMAC, 1024 words each) -> region 0.
    e.movi(R_T1, 1024);
    e.movi(R_LDTMP, 0);
    for v in 0..cfg.vmacs_per_cu {
        let unit = alloc.unit_for(StreamClass::Weights, 1024);
        e.movi(R_T0, (ctx.weights_addr + v * 1024) as i64);
        e.e(Instr::Ld {
            target: LdTarget::WBuf { cu: 0, vmac: v as u8 },
            broadcast: true,
            unit,
            rd: R_LDTMP,
            rs1: R_T0,
            rs2: R_T1,
        });
    }
    // Zero biases: 64 zero words -> BBuf, VMOV wide.
    {
        let unit = alloc.unit_for(StreamClass::Bias, 64);
        e.movi(R_T0, ctx.zero_addr as i64);
        e.movi(R_T1, 64);
        e.movi(R_LDTMP, 0);
        e.e(Instr::Ld {
            target: LdTarget::BBuf { cu: 0 },
            broadcast: true,
            unit,
            rd: R_LDTMP,
            rs1: R_T0,
            rs2: R_T1,
        });
        e.movi(R_T0, 0);
        e.e(Instr::Vmov { sel: VmovSel::Bias, rs1: R_T0, wide: true });
    }
    e.movi(28, 1); // lanes write adjacent channels
    e.movi(31, 0); // CUs redundant
    blocks.push(e.prog);

    // Compute blocks: split chunks across blocks to respect bank size.
    let taps = d.kh * d.kw;
    let per_chunk_instrs = if resident { taps * 3 + 8 } else { taps * 7 + 16 };
    let chunks_per_block = ((cfg.icache_bank_instrs - 16) / per_chunk_instrs).max(1);
    let mut chunk = 0usize;
    while chunk < d.chunks * d.h_out * d.w_out {
        let mut e = Emitter::new(cfg, ctx.opts.smart_delay_slots);
        for _ in 0..chunks_per_block {
            if chunk >= d.chunks * d.h_out * d.w_out {
                break;
            }
            let c0 = (chunk % d.chunks) * 64;
            let pix = chunk / d.chunks;
            let (oy, ox) = (pix / d.w_out, pix % d.w_out);
            let iy0 = oy * d.stride + ctx.in_cv.mp;
            let ix0 = ox * d.stride + ctx.in_cv.mp;
            if !resident {
                // Gather path: DMA each tap's 64-word channel slice into
                // a packed MBuf staging area [tap*64 ..].
                e.movi(R_T1, 64);
                for (t, (fy, fx)) in
                    (0..d.kh).flat_map(|fy| (0..d.kw).map(move |fx| (fy, fx))).enumerate()
                {
                    let src = ctx.in_cv.base
                        + (iy0 + fy) * ctx.in_cv.row_words()
                        + (ix0 + fx) * d.c_pad
                        + c0;
                    let unit = alloc.unit_for(StreamClass::Maps, 64);
                    e.movi(R_LDTMP, (t * 64) as i64);
                    e.movi(R_T0, src as i64);
                    e.e(Instr::Ld {
                        target: LdTarget::MBuf { cu: 0, bank: 0 },
                        broadcast: true,
                        unit,
                        rd: R_LDTMP,
                        rs1: R_T0,
                        rs2: R_T1,
                    });
                }
            }
            // MBuf address of the first tap.
            let m0 = if resident {
                (iy0 as i64) * row_words_in + ((ix0 * d.c_pad + c0) as i64)
            } else {
                0
            };
            e.movi(R_MTRACE, m0);
            e.movi(R_OUT, ctx.out_cv.addr_u(c0, oy, ox) as i64);
            e.movi(R_WTRACE, 0);
            for fy in 0..d.kh {
                for fx in 0..d.kw {
                    let first = fy == 0 && fx == 0;
                    let last = fy == d.kh - 1 && fx == d.kw - 1;
                    e.e(Instr::Mac {
                        coop: false,
                        rd: R_OUT,
                        rs1: R_MTRACE,
                        rs2: R_WTRACE,
                        len: 64,
                        flags: MacFlags {
                            reset: first,
                            writeback: last,
                            relu: false,
                            bypass: false,
                        },
                    });
                    if !last {
                        if !resident {
                            e.addi(R_MTRACE, R_MTRACE, 64);
                        } else if fx + 1 < d.kw {
                            e.addi(R_MTRACE, R_MTRACE, d.c_pad as i64);
                        } else {
                            e.addi(
                                R_MTRACE,
                                R_MTRACE,
                                row_words_in - ((d.kw - 1) * d.c_pad) as i64,
                            );
                        }
                    }
                }
            }
            chunk += 1;
        }
        blocks.push(e.prog);
    }
    blocks
}
