//! Emission helpers: the static register map (§5.2 "register assignment
//! is statically defined to avoid unnecessary register saving
//! instructions"), wide-immediate materialization, and the counted-loop
//! builder with branch-delay-slot filling.

use crate::arch::SnowflakeConfig;
use crate::isa::instr::{Instr, Program, Reg};

// ---------------------------------------------------------------------
// Static register assignment (r0 hardwired zero; r28..r31 reserved by
// the ISA conventions in `isa::instr`).
// ---------------------------------------------------------------------
pub const R_MROW: Reg = 1; //  maps strip row base (MBuf)
pub const R_MWIN: Reg = 2; //  window base (advances along x)
pub const R_WTRACE: Reg = 3; // weight trace address
pub const R_MTRACE: Reg = 4; // maps trace address
pub const R_ROWFIX: Reg = 5; // const: row_words_in - row_read
pub const R_OUT: Reg = 6; //   output address
pub const R_BIAS: Reg = 7; //  bbuf bias address (kg*4)
pub const R_BYP: Reg = 8; //   bbuf bypass address
pub const R_XC: Reg = 9; //    x loop counter
pub const R_XL: Reg = 10; //   x loop limit
pub const R_YC: Reg = 11; //   y loop counter
pub const R_YL: Reg = 12; //   y loop limit
pub const R_KC: Reg = 13; //   kernel-group loop counter
pub const R_KL: Reg = 14; //   kernel-group loop limit
pub const R_T0: Reg = 15; //   temp (byp row base / LD buf target)
pub const R_T1: Reg = 16; //   temp (out row base / LD length)
pub const R_ROWW_IN: Reg = 17; // const: input canvas row words
pub const R_XADV: Reg = 18; //  const: stride*c_pad_in (or pool lane stride)
pub const R_ROWW_OUT: Reg = 19; // const: output canvas row words
pub const R_CPO: Reg = 20; //   const: c_pad_out
pub const R_KMEM: Reg = 21; //  next kernel group DRAM address
pub const R_WREG: Reg = 22; //  current WBuf compute-region base
pub const R_LDTMP: Reg = 23; // LD memory-address scratch
pub const R_KW: Reg = 24; //    const: kernel_words
pub const R_YADV: Reg = 25; //  const: stride*row_words_in
pub const R_OUTBASE: Reg = 26; // tile output base
pub const R_MISC: Reg = 27; //  const: bypass-canvas row words / misc
pub const R_NOP: Reg = 29; //   no-op scratch (also large-imm staging)
pub const R_REGION: Reg = 30; // const: WBuf region words (double buffer)

/// Instruction emitter over one block.
pub struct Emitter<'a> {
    pub prog: Program,
    pub cfg: &'a SnowflakeConfig,
    /// Fill branch delay slots with useful tail instructions (hand
    /// optimization); false emits no-ops after the branch instead.
    pub smart: bool,
}

impl<'a> Emitter<'a> {
    pub fn new(cfg: &'a SnowflakeConfig, smart: bool) -> Self {
        Emitter { prog: Program::new(), cfg, smart }
    }

    pub fn e(&mut self, i: Instr) {
        self.prog.push(i);
    }

    pub fn c(&mut self, i: Instr, comment: &str) {
        self.prog.push_commented(i, comment);
    }

    pub fn len(&self) -> usize {
        self.prog.len()
    }

    /// No-op (architecturally: `addi r29, r0, 0`).
    pub fn nop(&mut self) {
        self.e(Instr::Addi { rd: R_NOP, rs1: 0, imm: 0 });
    }

    /// Materialize an arbitrary value into `rd` (1–3 instructions).
    pub fn movi(&mut self, rd: Reg, val: i64) {
        if (-(1 << 22)..(1 << 22)).contains(&val) {
            self.e(Instr::Movi { rd, imm: val as i32 });
        } else {
            // val = hi << 11 + lo, lo in [0, 2048).
            let lo = val & 0x7ff;
            let hi = val >> 11;
            assert!(hi < (1 << 22), "movi value out of range: {val}");
            self.e(Instr::Movi { rd, imm: hi as i32 });
            self.e(Instr::Mov { rd, rs1: rd, sh: 11 });
            if lo != 0 {
                self.e(Instr::Addi { rd, rs1: rd, imm: lo as i16 });
            }
        }
    }

    /// `rd = rs + delta` for arbitrary delta (1 or 3 instructions; uses
    /// r29 as staging for wide deltas).
    pub fn addi(&mut self, rd: Reg, rs: Reg, delta: i64) {
        if delta == 0 && rd == rs {
            return;
        }
        if (-2048..=2047).contains(&delta) {
            self.e(Instr::Addi { rd, rs1: rs, imm: delta as i16 });
        } else {
            self.movi(R_NOP, delta);
            self.e(Instr::Add { rd, rs1: rs, rs2: R_NOP });
        }
    }

    /// Counted loop: runs `body` `n` times. `tail` returns up to 4
    /// iteration-epilogue instructions (safe to run every iteration,
    /// mutually independent) used to fill the branch delay slots in
    /// smart mode; in plain mode they run before the branch and the
    /// slots are no-ops — the instruction-count-vs-latency trade of
    /// §5.2.
    pub fn counted_loop<B, T>(&mut self, cnt: Reg, lim: Reg, n: usize, body: B, tail: T)
    where
        B: FnOnce(&mut Self),
        T: FnOnce(&mut Self, bool),
    {
        if n == 0 {
            return;
        }
        if n == 1 {
            body(self);
            tail(self, false);
            return;
        }
        self.movi(cnt, 0);
        self.movi(lim, n as i64 - 1);
        let start = self.prog.len();
        body(self);
        if self.smart {
            self.e(Instr::Addi { rd: cnt, rs1: cnt, imm: 1 });
            let off = start as i64 - self.prog.len() as i64;
            self.e(Instr::Ble { rs1: cnt, rs2: lim, off: off as i16 });
            let before = self.prog.len();
            tail(self, true);
            let emitted = self.prog.len() - before;
            assert!(emitted <= self.cfg.branch_delay_slots, "tail too long for delay slots");
            for _ in emitted..self.cfg.branch_delay_slots {
                self.nop();
            }
        } else {
            tail(self, false);
            self.e(Instr::Addi { rd: cnt, rs1: cnt, imm: 1 });
            let off = start as i64 - self.prog.len() as i64;
            self.e(Instr::Ble { rs1: cnt, rs2: lim, off: off as i16 });
            for _ in 0..self.cfg.branch_delay_slots {
                self.nop();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q8_8;
    use crate::sim::Machine;

    fn run(prog: Program) -> Machine {
        let mut m = Machine::new(SnowflakeConfig::default(), Q8_8, 1024);
        let mut p = prog;
        p.push(Instr::Halt);
        crate::isa::verify::assert_valid(&p.instrs, &m.cfg);
        m.load_program(p.instrs);
        m.run().expect("run");
        m
    }

    #[test]
    fn movi_wide_values() {
        let cfg = SnowflakeConfig::default();
        for &val in &[0i64, 1, -1, 2047, 4_194_303, 4_194_304, 20_000_000, (1 << 30) + 12345] {
            let mut e = Emitter::new(&cfg, false);
            e.movi(1, val);
            let m = run(e.prog);
            assert_eq!(m.regs[1], val, "val {val}");
        }
    }

    #[test]
    fn addi_wide_deltas() {
        let cfg = SnowflakeConfig::default();
        for &d in &[0i64, 5, -2048, 2047, 2048, 100_000, -1_000_000] {
            let mut e = Emitter::new(&cfg, false);
            e.movi(1, 7);
            e.addi(2, 1, d);
            let m = run(e.prog);
            assert_eq!(m.regs[2], 7 + d, "delta {d}");
        }
    }

    #[test]
    fn counted_loop_runs_n_times() {
        let cfg = SnowflakeConfig::default();
        for smart in [false, true] {
            for n in [1usize, 2, 7] {
                let mut e = Emitter::new(&cfg, smart);
                e.counted_loop(
                    R_XC,
                    R_XL,
                    n,
                    |e| e.e(Instr::Addi { rd: 5, rs1: 5, imm: 1 }),
                    |e, _| e.e(Instr::Addi { rd: 6, rs1: 6, imm: 1 }),
                );
                let m = run(e.prog);
                assert_eq!(m.regs[5], n as i64, "body n={n} smart={smart}");
                assert_eq!(m.regs[6], n as i64, "tail n={n} smart={smart}");
            }
        }
    }

    #[test]
    fn nested_loops() {
        let cfg = SnowflakeConfig::default();
        let mut e = Emitter::new(&cfg, true);
        e.counted_loop(
            R_YC,
            R_YL,
            3,
            |e| {
                e.counted_loop(
                    R_XC,
                    R_XL,
                    5,
                    |e| e.e(Instr::Addi { rd: 5, rs1: 5, imm: 1 }),
                    |e, _| e.e(Instr::Addi { rd: 6, rs1: 6, imm: 1 }),
                );
            },
            |e, _| e.e(Instr::Addi { rd: 7, rs1: 7, imm: 1 }),
        );
        let m = run(e.prog);
        assert_eq!(m.regs[5], 15);
        assert_eq!(m.regs[6], 15);
        assert_eq!(m.regs[7], 3);
    }

    #[test]
    fn smart_loops_are_shorter() {
        let cfg = SnowflakeConfig::default();
        let mk = |smart: bool| {
            let mut e = Emitter::new(&cfg, smart);
            e.counted_loop(
                R_XC,
                R_XL,
                4,
                |e| e.e(Instr::Addi { rd: 5, rs1: 5, imm: 1 }),
                |e, _| {
                    e.e(Instr::Addi { rd: 6, rs1: 6, imm: 1 });
                    e.e(Instr::Addi { rd: 7, rs1: 7, imm: 1 });
                },
            );
            e.prog.len()
        };
        assert!(mk(true) < mk(false));
    }
}
