//! In-process memoized measurement cache for `TuneMode::Measured`
//! (ROADMAP carry-over, ISSUE 8 satellite).
//!
//! The measured tuner (`coordinator/tune.rs`) simulates top-K schedule
//! candidates per conv layer — minutes of full-model simulation for a
//! verdict that is a pure function of (hardware config, layer
//! geometry). This cache publishes each winning per-layer schedule
//! under that key, so a later `compile()` under
//! [`super::TuneMode::Measured`] picks the measured winner directly
//! instead of passing through to the analytical search: identical
//! layers shared *across models* (every 3x3x512 ResNet block, say)
//! are measured once and reused everywhere.
//!
//! Correctness: a cache hit only ever changes *which* valid schedule a
//! layer compiles under — a stale or cross-layer entry whose
//! `rows_per_cu` no longer fits the caps fails [`cost::validate`] and
//! is treated as a miss (analytical fallback), never an error.

use super::cost::{self, ConvGeom, Schedule};
use crate::arch::SnowflakeConfig;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Cache key: the config fingerprint plus every schedule-independent
/// geometry field the cost model reads. `byp_row_words` is deliberately
/// excluded — `decide` keys costs on a conservative bypass-row estimate
/// while the tuner sees the placed canvas's exact row words, and two
/// layers differing only there are the same schedule-selection problem.
type Key = (u64, [u64; 10], bool, bool);

fn key(cfg: &SnowflakeConfig, g: &ConvGeom) -> Key {
    (
        super::artifact::config_hash(cfg),
        [
            g.kh as u64,
            g.stride as u64,
            g.h_out as u64,
            g.w_out as u64,
            g.row_words_in as u64,
            g.row_read as u64,
            g.n_segs as u64,
            g.kernel_words as u64,
            g.k_groups as u64,
            g.max_rows as u64,
        ],
        g.has_bypass,
        g.dbuf_w,
    )
}

fn cache() -> &'static Mutex<HashMap<Key, Schedule>> {
    static CACHE: OnceLock<Mutex<HashMap<Key, Schedule>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

/// Hit/miss counters since process start (process-wide totals —
/// tests assert on deltas, not absolutes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

pub fn counters() -> CacheCounters {
    CacheCounters {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
        entries: cache().lock().expect("measure cache poisoned").len(),
    }
}

/// Look up the measured winner for a layer geometry. Counts a hit only
/// when a *valid* schedule comes back; an absent or cap-violating entry
/// counts as a miss and returns `None` (caller falls back to the
/// analytical search).
pub fn lookup(cfg: &SnowflakeConfig, g: &ConvGeom) -> Option<Schedule> {
    let found = cache().lock().expect("measure cache poisoned").get(&key(cfg, g)).copied();
    match found {
        Some(s) if cost::validate(&s, g, cfg).is_ok() => {
            HITS.fetch_add(1, Ordering::Relaxed);
            Some(s)
        }
        _ => {
            MISSES.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

/// Publish a measured winner (latest measurement wins on re-tune).
pub fn record(cfg: &SnowflakeConfig, g: &ConvGeom, s: Schedule) {
    cache().lock().expect("measure cache poisoned").insert(key(cfg, g), s);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{BalancePolicy, LoopOrder};

    fn geom(kernel_words: usize) -> ConvGeom {
        ConvGeom {
            kh: 3,
            stride: 1,
            h_out: 16,
            w_out: 16,
            row_words_in: 1234,
            row_read: 48,
            n_segs: 1,
            kernel_words,
            k_groups: 4,
            c_pad_out: 16,
            has_bypass: false,
            byp_row_words: 0,
            max_rows: 4,
            dbuf_w: true,
        }
    }

    #[test]
    fn record_then_lookup_hits_and_validates() {
        let cfg = SnowflakeConfig::default();
        // Unique kernel_words so no other test's entries collide.
        let g = geom(98_761);
        let before = counters();
        assert_eq!(lookup(&cfg, &g), None, "empty key must miss");
        let s = Schedule {
            order: LoopOrder::Kloop,
            rows_per_cu: 2,
            policy: BalancePolicy::Greedy { split: 2 },
        };
        record(&cfg, &g, s);
        assert_eq!(lookup(&cfg, &g), Some(s));
        // An entry that violates the geometry caps is a miss, not a
        // panic: rows_per_cu 9 > max_rows 4.
        let bad =
            Schedule { order: LoopOrder::Kloop, rows_per_cu: 9, policy: BalancePolicy::default() };
        record(&cfg, &g, bad);
        assert_eq!(lookup(&cfg, &g), None, "cap-violating entry must read as a miss");
        let after = counters();
        assert!(after.hits >= before.hits + 1);
        assert!(after.misses >= before.misses + 2);
        // A different config never sees the entry.
        let other = SnowflakeConfig { link_bandwidth_gbs: 9.0, ..SnowflakeConfig::default() };
        record(&cfg, &g, s);
        assert_eq!(lookup(&other, &g), None, "config fingerprint partitions the cache");
    }
}
