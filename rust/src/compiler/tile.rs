//! Step 4 of model parsing (§5.1): workload breakdown into buffer-sized
//! tiles. "The maps are decomposed in tiles with output row granularity"
//! — a map tile is a group of output-row strips, one strip per CU;
//! "Weights are decomposed in tiles with single kernel granularity" — a
//! kernel tile is a group of 4 kernels (one per vMAC).

use crate::arch::SnowflakeConfig;

/// One map tile: each CU produces `rows_per_cu` consecutive output rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MapTile {
    pub index: usize,
    /// First output row of CU 0's strip.
    pub oy0: usize,
    pub rows_per_cu: usize,
    /// MBuf bank this tile's strips load into (double buffering).
    pub bank: usize,
}

impl MapTile {
    /// First output row of a given CU's strip.
    pub fn cu_oy0(&self, cu: usize) -> usize {
        self.oy0 + cu * self.rows_per_cu
    }

    /// Input rows each strip spans for a (kh, stride) window op.
    pub fn in_rows(&self, kh: usize, stride: usize) -> usize {
        (self.rows_per_cu - 1) * stride + kh
    }
}

/// Decompose `h_out` output rows into map tiles. The caller guarantees
/// `span = rows_per_cu * n_cus <= h_out`; the final tile is shifted
/// *backwards* to end exactly at the last row — overlapping rows are
/// recomputed (idempotent writes) instead of overshooting into the
/// consumer's zero-padding margin.
pub fn map_tiles(h_out: usize, base_rows: usize, cfg: &SnowflakeConfig) -> Vec<MapTile> {
    assert!(cfg.n_cus <= h_out, "output rows {h_out} below CU count");
    let mut tiles = Vec::new();
    let mut next = 0usize;
    let mut i = 0usize;
    while next < h_out {
        let remaining = h_out - next;
        // Shrink the tail tile instead of recomputing a full span.
        let rows = base_rows.min(remaining.div_ceil(cfg.n_cus)).max(1);
        let span = rows * cfg.n_cus;
        let oy0 = if next + span <= h_out { next } else { h_out - span };
        tiles.push(MapTile { index: i, oy0, rows_per_cu: rows, bank: i % cfg.mbuf_banks });
        next = oy0 + span;
        i += 1;
    }
    tiles
}

/// Per-tile `rows_per_cu` of the decomposition [`map_tiles`] produces,
/// as a plain function of the shape — the cost model predicts tile
/// structure for candidate schedules without building `MapTile`s (and
/// without a config; `n_cus` is passed explicitly). Must stay in
/// lockstep with [`map_tiles`]; pinned by the property test below.
pub fn tile_rows(h_out: usize, base_rows: usize, n_cus: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut next = 0usize;
    while next < h_out {
        let remaining = h_out - next;
        let rows = base_rows.min(remaining.div_ceil(n_cus)).max(1);
        let span = rows * n_cus;
        let oy0 = if next + span <= h_out { next } else { h_out.saturating_sub(span) };
        out.push(rows);
        next = oy0 + span;
    }
    out
}

/// One kernel tile: 4 consecutive kernels (output channels), one per
/// vMAC; `region` is the WBuf double-buffer region it occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelTile {
    pub index: usize,
    pub k0: usize,
}

/// Kernel tiles for `k_groups` groups of 4.
pub fn kernel_tiles(k_groups: usize) -> Vec<KernelTile> {
    (0..k_groups).map(|i| KernelTile { index: i, k0: i * 4 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiles_cover_all_rows_without_overshoot() {
        let cfg = SnowflakeConfig::default();
        // 27 rows, base 6: full tile (24 rows) + 1-row tail shifted to
        // end exactly at 27 (one overlap row, not 21).
        let tiles = map_tiles(27, 6, &cfg);
        assert_eq!(tiles.len(), 2);
        assert_eq!(tiles[0].oy0, 0);
        assert_eq!(tiles[0].rows_per_cu, 6);
        assert_eq!(tiles[1].rows_per_cu, 1);
        assert_eq!(tiles[1].oy0, 23);
        let total: usize = tiles.iter().map(|t| t.rows_per_cu * 4).sum();
        assert_eq!(total, 28); // only 1 redundant row
        // Even-ish split: three full tiles + a shrunken 2-row tail.
        let tiles = map_tiles(56, 4, &cfg);
        assert_eq!(tiles.len(), 4);
        assert_eq!(tiles[1].oy0, 16);
        assert_eq!(tiles[1].bank, 1);
        assert_eq!(tiles[2].bank, 0);
        assert_eq!(tiles[3].rows_per_cu, 2);
        assert_eq!(tiles[3].oy0 + 2 * 4, 56);
        let total: usize = tiles.iter().map(|t| t.rows_per_cu * 4).sum();
        assert_eq!(total, 56); // zero redundancy on this shape
    }

    #[test]
    #[should_panic]
    fn too_few_rows_panics() {
        let cfg = SnowflakeConfig::default();
        map_tiles(3, 2, &cfg);
    }

    #[test]
    fn strip_row_math() {
        let t = MapTile { index: 0, oy0: 0, rows_per_cu: 7, bank: 0 };
        // 7 output rows, 5x5 stride 1 -> 11 input rows.
        assert_eq!(t.in_rows(5, 1), 11);
        // stride 2, 3x3 -> 15.
        assert_eq!(t.in_rows(3, 2), 15);
    }

    #[test]
    fn kernel_tiles_step_by_four() {
        let ks = kernel_tiles(48);
        assert_eq!(ks.len(), 48);
        assert_eq!(ks[47].k0, 188);
    }

    /// Property test over randomized (h_out, base_rows): tiles cover
    /// exactly `0..h_out`, the tail tile shifts back without
    /// overshooting, banks alternate, and `tile_rows` stays in lockstep
    /// with `map_tiles`.
    #[test]
    fn map_tiles_invariants_hold_under_random_shapes() {
        crate::util::prop::for_cases(200, 0x7113, |rng| {
            let cfg = SnowflakeConfig::default();
            let h_out = rng.range(cfg.n_cus, 240);
            // base_rows respects the decide() cap: at most h_out / n_cus.
            let base_rows = rng.range(1, (h_out / cfg.n_cus).max(1) + 1);
            let tiles = map_tiles(h_out, base_rows, &cfg);
            assert!(!tiles.is_empty());

            let mut covered = vec![false; h_out];
            for (i, t) in tiles.iter().enumerate() {
                // Structural invariants.
                assert_eq!(t.index, i, "indices consecutive");
                assert_eq!(t.bank, i % cfg.mbuf_banks, "bank alternation");
                assert!(t.rows_per_cu >= 1 && t.rows_per_cu <= base_rows);
                // No overshoot: the tile's span ends inside the map (the
                // tail tile shifts *back* instead of spilling past it).
                let span = t.rows_per_cu * cfg.n_cus;
                assert!(
                    t.oy0 + span <= h_out,
                    "tile {i} [{}..{}) overshoots h_out {h_out}",
                    t.oy0,
                    t.oy0 + span
                );
                for r in t.oy0..t.oy0 + span {
                    covered[r] = true;
                }
            }
            // Exact coverage: every output row produced at least once.
            assert!(
                covered.iter().all(|&c| c),
                "rows uncovered (h_out {h_out}, base {base_rows})"
            );
            // Non-tail tiles keep the full height and advance
            // contiguously; only the final tile may shrink/shift back.
            for pair in tiles.windows(2) {
                assert_eq!(pair[0].rows_per_cu, base_rows, "only the tail tile may shrink");
                assert!(
                    pair[1].oy0 <= pair[0].oy0 + pair[0].rows_per_cu * cfg.n_cus,
                    "gap between consecutive tiles"
                );
            }
            // tile_rows (the cost model's view) matches map_tiles.
            let rows: Vec<usize> = tiles.iter().map(|t| t.rows_per_cu).collect();
            assert_eq!(rows, tile_rows(h_out, base_rows, cfg.n_cus), "tile_rows diverged");
        });
    }
}
