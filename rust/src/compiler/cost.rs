//! Analytical schedule cost model and per-layer schedule search.
//!
//! The seed compiler decided conv schedules with a fixed heuristic:
//! maximize `rows_per_cu` to buffer capacity and compare two closed-form
//! traffic numbers for the loop order (§6.2), with the maps-split factor
//! and balance policy fixed globally. This module replaces that with
//! design-space exploration over an analytical performance model, the
//! way the related FPGA compilation flows (fpgaConvNet, the DPU flow)
//! pick per-layer configurations: every candidate schedule — loop order
//! × tile height × maps-split factor × balance policy — gets a
//! predicted cycle count and off-chip byte count, and the compiler keeps
//! the argmin.
//!
//! ## What the model models
//!
//! * **CU occupancy**: every vector MAC is broadcast to all CUs, so the
//!   per-CU serial work is `k_groups × Σ_tile rows × w_out` windows of
//!   `kh·row_read/16 + gather` cycles — including the *redundant* rows a
//!   back-shifted tail tile recomputes.
//! * **Issue bandwidth**: one instruction per cycle; per-window MAC +
//!   trace-advance instructions plus loop-control overhead (branch delay
//!   slots are 4 no-ops in plain mode, folded tails in smart mode).
//! * **DMA**: total off-chip bytes at the fair-shared AXI budget, plus
//!   per-unit serialization — a unit pays `dma_setup_cycles` per stream
//!   before transferring, so stream count (the split factor) and the
//!   unit distribution (the balance policy) both matter. The per-unit
//!   distribution is approximated per policy: even for Greedy, split by
//!   class for TwoUnits, everything on unit 0 for OneUnit.
//! * **Startup**: the serial prefix before the first window can run —
//!   tile-0 map strips (all resident strips for Mloop) and kernel
//!   group 0.
//!
//! The layer estimate is `startup + max(compute, issue, dma) + drain`:
//! double-buffered prefetch overlaps steady-state phases, so the slowest
//! resource governs. The model also charges two second-order effects the
//! seed version ignored (ROADMAP follow-ons, ISSUE 5): **icache reload
//! traffic** — every emitted block costs one bank image
//! (`icache_bank_instrs × 2` words) of off-chip reads, which is what
//! dominates very small layers — and the **cross-layer Greedy byte
//! memory**: the allocator balances whole streams against byte counters
//! carried across layer boundaries, so a unit can lead or lag its fair
//! share by about half the largest single stream; the Greedy per-unit
//! estimate adds that skew instead of assuming perfect division.
//! **Still deliberately ignored**: RAW/queue-depth issue stalls,
//! scoreboard wait tails at tile boundaries, and DMA quota re-sharing
//! as streams come and go. The documented error bound is a factor of
//! `MODEL_ERROR_BOUND` per conv layer versus the event core (typically
//! well inside ±30%; `benches/tuning.rs` asserts the bound per layer,
//! and `repro tune` re-checks it on every invocation).
//!
//! ## The banked-rotation Mloop (ISSUE 5)
//!
//! The resident Mloop skeleton requires every map strip in its own MBuf
//! bank (`n_tiles ≤ mbuf_banks`), so the tall early layers fall back to
//! Kloop and re-stream the kernels once per tile. [`LoopOrder::MloopRot`]
//! removes that cap: kernel *sets* — as many groups as fit one WBuf
//! region ([`rot_sets`]) — stay resident while the map strips rotate
//! through the banks with a `mbuf_banks − 1`-step prefetch, so the
//! kernel stream is still read **exactly once** for any tile count. The
//! price is one pass over the map strips per kernel set:
//! `maps_reread = maps_once × (passes − 1)`. The search therefore picks
//! rotation exactly when `kernels_once × (n_tiles − 1) > maps_reread`
//! (plus the smaller startup: only `mbuf_banks − 1` strips stage before
//! the first window, versus all of them for the resident skeleton). In
//! the single-set case (`passes == 1`) rotation strictly dominates
//! Kloop on traffic for every multi-tile layer.
//!
//! ## The candidate space
//!
//! * loop order: Kloop always; Mloop only where the maps-resident
//!   skeleton exists (no fused bypass, `2 ≤ n_tiles ≤ mbuf_banks`, the
//!   unrolled tile loop fits an icache bank block); MloopRot wherever
//!   the rotation skeleton is emittable ([`mloop_rot_viable`]).
//! * `rows_per_cu`: 1..=8, the capacity cap and cap−1, and the heights
//!   that give exactly 1..=4 tiles — a bounded, deduplicated set.
//! * maps split: {1, 2, 4, 8} (∪ the user's split) under Greedy.
//! * balance policy: the Greedy family; a non-Greedy base policy pins
//!   every candidate to it so Table-3-style experiments stay meaningful.
//!
//! Ties keep the seed heuristic's schedule, and a candidate must beat
//! the seed's prediction by [`DISPLACE_MARGIN_PCT`] percent to displace
//! it, so tuned output only deviates where the model predicts a real
//! win (e.g. the Mloop flip on kernel-dominated two-tile layers, worth
//! ~10% cycles and ~2x traffic on ResNet18's layer-4 convs).

use super::decide::CONV_SPILL_ROWS;
use super::{BalancePolicy, CompileOptions, LoopOrder};
use crate::arch::SnowflakeConfig;
use crate::compiler::tile::tile_rows;

/// Instruction budget for the Mloop single-block skeleton: the icache
/// bank minus the reload-prologue slots and headroom for estimate
/// error (72 = 8 prologue slots + 64 estimate margin; 440 on the
/// default 512-instruction bank). Scales with retargeted configs.
fn mloop_block_budget(cfg: &SnowflakeConfig) -> usize {
    cfg.icache_bank_instrs.saturating_sub(72)
}

/// Documented worst-case ratio between predicted and event-core
/// measured cycles per conv layer (either direction). Asserted by
/// `benches/tuning.rs`.
pub const MODEL_ERROR_BOUND: f64 = 3.0;

/// Minimum predicted improvement (percent) before the search displaces
/// the seed heuristic's schedule. Sub-threshold deltas are inside the
/// model's noise floor, and honoring them would churn schedules (e.g.
/// shaving tile heights for a 0.5% predicted startup win while
/// multiplying kernel re-streams); with the margin, tuned output
/// deviates from the seed only where the model predicts a real win.
pub const DISPLACE_MARGIN_PCT: u64 = 2;

/// One candidate conv schedule: the §6.2 loop order, the map-tile
/// height, and the LD balance policy (whose Greedy split factor is the
/// §6.3 maps-split knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Schedule {
    pub order: LoopOrder,
    pub rows_per_cu: usize,
    pub policy: BalancePolicy,
}

impl Schedule {
    /// Pieces each per-CU maps strip load is split into.
    pub fn split(&self) -> usize {
        match self.policy {
            BalancePolicy::Greedy { split } => split.max(1),
            _ => 1,
        }
    }
}

/// Conv geometry the model needs — everything `decide` derives before
/// schedule selection, independent of the schedule itself.
#[derive(Clone, Copy, Debug)]
pub struct ConvGeom {
    pub kh: usize,
    pub stride: usize,
    pub h_out: usize,
    pub w_out: usize,
    /// Input canvas row words (margin/slack inclusive).
    pub row_words_in: usize,
    /// Window-row read length (padded to vector words).
    pub row_read: usize,
    /// Trace segments per window row.
    pub n_segs: usize,
    pub kernel_words: usize,
    pub k_groups: usize,
    pub c_pad_out: usize,
    pub has_bypass: bool,
    /// Bypass canvas row words (0 without bypass).
    pub byp_row_words: usize,
    /// Constraint cap on `rows_per_cu` (MBuf bank, BBuf bypass budget,
    /// `h_out / n_cus` floor).
    pub max_rows: usize,
    pub dbuf_w: bool,
}

/// Predicted performance of one (layer, schedule) pair.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CostEstimate {
    /// Predicted end-to-end cycles for the layer.
    pub cycles: u64,
    /// Predicted off-chip traffic (loads + stores) in bytes.
    pub dram_bytes: u64,
    /// Resource-bound components (`cycles ≈ startup + max of these + drain`).
    pub compute_cycles: u64,
    pub issue_cycles: u64,
    pub dma_cycles: u64,
    pub startup_cycles: u64,
    /// DMA streams the layer issues (setup-cost driver).
    pub streams: u64,
}

/// Instruction-count estimate of one emitted window (MACs + trace
/// advances), shared by the issue model and the Mloop block-size check.
fn window_instrs(g: &ConvGeom) -> usize {
    // 2 trace-base adds, kh·n_segs MACs, 2 advances per non-final
    // segment, one row-fix add per non-final row, bypass VMOV.
    2 + g.kh * g.n_segs + 2 * (g.kh * g.n_segs - 1) + (g.kh - 1) + g.has_bypass as usize
}

/// Static instruction estimate of the Mloop single-block skeleton
/// (kernel-group loop with the tile loop unrolled inside).
fn mloop_block_instrs(g: &ConvGeom, n_tiles: usize) -> usize {
    50 + n_tiles * (45 + window_instrs(g))
}

/// Whether the maps-resident Mloop skeleton can serve this layer at the
/// given tile height: no fused bypass (the bypass strip is reloaded per
/// tile, which only the Kloop skeleton stages), every strip resident in
/// its own MBuf bank, and the unrolled block inside one icache bank.
pub fn mloop_viable(g: &ConvGeom, cfg: &SnowflakeConfig, rows_per_cu: usize) -> bool {
    if g.has_bypass {
        return false;
    }
    let n_tiles = tile_rows(g.h_out, rows_per_cu, cfg.n_cus).len();
    n_tiles >= 2
        && n_tiles <= cfg.mbuf_banks
        && mloop_block_instrs(g, n_tiles) <= mloop_block_budget(cfg)
}

/// Kernel-set residency of the banked-rotation skeleton:
/// `(groups_per_set, passes)`. A set is as many kernel groups as fit
/// one WBuf region — sets never straddle the region boundary, because
/// the simulator's scoreboard tracks fills per region and a straddling
/// fill would leave its tail unguarded. `passes` map-strip passes cover
/// all `k_groups` (each group is loaded in exactly one set, so the
/// kernel stream is read once regardless of the pass count).
pub fn rot_sets(kernel_words: usize, k_groups: usize, cfg: &SnowflakeConfig) -> (usize, usize) {
    let per = (cfg.wbuf_region_words() / kernel_words.max(1)).max(1).min(k_groups.max(1));
    (per, k_groups.max(1).div_ceil(per))
}

/// Maps-strip pieces one per-CU strip load is split into (mirrors
/// `codegen/conv.rs::emit_maps_loads`).
fn strip_pieces(strip_words: usize, split: usize) -> usize {
    split.max(1).min(strip_words.div_ceil(64)).max(1)
}

/// Static instruction estimate of one banked-rotation *pass* block
/// (kernel-set load loop + the unrolled tile walk, each tile carrying
/// its strip prefetch and the resident-group/row/column loop nest).
/// Deliberately an over-estimate of the real emission (wide-immediate
/// movi worst cases included) so a schedule the tuner accepts can never
/// overflow its icache bank at codegen time; `tests/rotation.rs` pins
/// the bound against actual emitted blocks.
fn mloop_rot_block_instrs(g: &ConvGeom, cfg: &SnowflakeConfig, rows_per_cu: usize, split: usize) -> usize {
    let rows_list = tile_rows(g.h_out, rows_per_cu, cfg.n_cus);
    let strip = ((rows_per_cu.max(1) - 1) * g.stride + g.kh + CONV_SPILL_ROWS) * g.row_words_in;
    let pieces = strip_pieces(strip, split);
    30 + rows_list.len() * (window_instrs(g) + 50 + cfg.n_cus * pieces * 6)
}

/// Whether the banked-rotation Mloop skeleton can serve this layer at
/// the given tile height and maps-split: no fused bypass, at least two
/// banks to rotate through, at least two tiles (a single tile is the
/// degenerate resident case), the kernel group inside a WBuf region
/// (`dbuf_w`, so a whole set fits without straddling regions), and each
/// pass block inside one icache bank.
pub fn mloop_rot_viable(
    g: &ConvGeom,
    cfg: &SnowflakeConfig,
    rows_per_cu: usize,
    split: usize,
) -> bool {
    if g.has_bypass || !g.dbuf_w || cfg.mbuf_banks < 2 {
        return false;
    }
    let n_tiles = tile_rows(g.h_out, rows_per_cu, cfg.n_cus).len();
    n_tiles >= 2 && mloop_rot_block_instrs(g, cfg, rows_per_cu, split) <= mloop_block_budget(cfg)
}

/// The loop order codegen will actually emit for a requested order.
/// `Mloop` means the Mloop family: the maps-resident skeleton where it
/// fits, the banked-rotation skeleton where only rotation can keep the
/// kernel stream single-pass.
pub fn effective_order(
    g: &ConvGeom,
    cfg: &SnowflakeConfig,
    order: LoopOrder,
    rows_per_cu: usize,
    split: usize,
) -> LoopOrder {
    match order {
        LoopOrder::Mloop if mloop_viable(g, cfg, rows_per_cu) => LoopOrder::Mloop,
        LoopOrder::Mloop | LoopOrder::MloopRot
            if mloop_rot_viable(g, cfg, rows_per_cu, split) =>
        {
            LoopOrder::MloopRot
        }
        _ => LoopOrder::Kloop,
    }
}

/// Predict cycles and traffic for one schedule. The schedule's order is
/// clamped to what codegen will emit ([`effective_order`]).
pub fn estimate(
    g: &ConvGeom,
    s: &Schedule,
    cfg: &SnowflakeConfig,
    smart_delay_slots: bool,
) -> CostEstimate {
    let split = s.split();
    let order = effective_order(g, cfg, s.order, s.rows_per_cu, split);
    let n_cus = cfg.n_cus as u64;
    let units = cfg.n_load_units as u64;
    let setup = cfg.dma_setup_cycles;
    let wb = cfg.word_bytes as u64;
    // Millibyte budget per cycle (exact for 16.8 B/cycle).
    let budget_mb = (cfg.axi_bytes_per_cycle * 1000.0).round().max(1.0) as u64;
    let bytes_to_cycles = |bytes: u64| (bytes * 1000).div_ceil(budget_mb);

    let rows_list = tile_rows(g.h_out, s.rows_per_cu, cfg.n_cus);
    let n_tiles = rows_list.len() as u64;
    let strip_words =
        |r: usize| ((r - 1) * g.stride + g.kh + CONV_SPILL_ROWS) * g.row_words_in;
    let pieces = |r: usize| strip_pieces(strip_words(r), split);

    // ---- traffic -----------------------------------------------------
    let maps_once: u64 = rows_list.iter().map(|&r| n_cus * strip_words(r) as u64).sum();
    let maps_streams_once: u64 = rows_list.iter().map(|&r| n_cus * pieces(r) as u64).sum();
    // Banked rotation re-streams every strip once per kernel-set pass
    // (the §6.2 trade: maps_reread buys kernel-traffic elimination).
    let (gset, rot_passes) = rot_sets(g.kernel_words, g.k_groups, cfg);
    let map_passes = if order == LoopOrder::MloopRot { rot_passes as u64 } else { 1 };
    let maps_words_all = maps_once * map_passes;
    let maps_streams = maps_streams_once * map_passes;
    let group_words = 4 * g.kernel_words as u64;
    // Each pass over the kernel stream loads k_groups real groups plus
    // the dummy prefetch group; rotation's sets partition the groups
    // (each loaded exactly once, no dummy prefetch needed).
    let (kernel_words_all, kernel_streams) = match order {
        LoopOrder::Kloop => (
            n_tiles * (g.k_groups as u64 + 1) * group_words,
            n_tiles * (g.k_groups as u64 + 1) * 4,
        ),
        LoopOrder::Mloop => ((g.k_groups as u64 + 1) * group_words, (g.k_groups as u64 + 1) * 4),
        LoopOrder::MloopRot => (g.k_groups as u64 * group_words, g.k_groups as u64 * 4),
    };
    let byp_words: u64 = if g.has_bypass {
        rows_list.iter().map(|&r| n_cus * (r * g.byp_row_words) as u64).sum()
    } else {
        0
    };
    let byp_streams = if g.has_bypass { n_tiles * n_cus } else { 0 };
    let bias_words = (g.k_groups * 4) as u64;
    // Icache reload traffic: every emitted block re-streams one bank
    // image (`bank_instrs` instructions × 2 words each). The seed model
    // ignored this; it is what dominates very small layers.
    let icache_blocks = match order {
        LoopOrder::Kloop => 1 + n_tiles,
        LoopOrder::Mloop => 2,
        LoopOrder::MloopRot => 1 + rot_passes as u64,
    };
    let icache_words = icache_blocks * cfg.icache_bank_instrs as u64 * 2;
    let windows_rows: u64 = rows_list.iter().map(|&r| r as u64).sum();
    let stores_words = g.k_groups as u64 * 4 * windows_rows * n_cus * g.w_out as u64;
    let loads_words = maps_words_all + kernel_words_all + byp_words + bias_words + icache_words;
    let dram_bytes = (loads_words + stores_words) * wb;
    let streams = maps_streams + kernel_streams + byp_streams + 1 + icache_blocks;

    // ---- compute (per-CU serial vector work) -------------------------
    let trace = (g.kh * g.row_read / 16) as u64;
    let win_cu = trace + cfg.gather_cycles + g.has_bypass as u64;
    let compute_cycles =
        g.k_groups as u64 * (windows_rows * g.w_out as u64 * win_cu + n_tiles);

    // ---- issue (1 instruction per cycle) -----------------------------
    let byp = g.has_bypass as u64;
    let win_issue = window_instrs(g) as u64;
    let xloop_over: u64 = if smart_delay_slots { 6 } else { 8 + byp };
    let per_y = (win_issue + xloop_over) * g.w_out as u64 + 13 + byp;
    let issue_cycles = g.k_groups as u64 * (windows_rows * per_y + n_tiles * 35)
        + streams * 5
        + 64;

    // ---- DMA ---------------------------------------------------------
    let bus_cycles = bytes_to_cycles(dram_bytes);
    let loads_bytes = loads_words * wb;
    let (worst_unit_streams, worst_unit_bytes) = match s.policy {
        BalancePolicy::OneUnit => (streams, loads_bytes),
        BalancePolicy::TwoUnits => {
            // Maps + icache on unit 0; weights + bias (+ bypass strips,
            // which the codegen issues as Bias-class streams) on unit 1
            // (`balance::UnitAllocator`'s class pinning).
            let u0 = (maps_streams + icache_blocks, (maps_words_all + icache_words) * wb);
            let u1 = (
                kernel_streams + byp_streams + 1,
                (kernel_words_all + byp_words + bias_words) * wb,
            );
            if u0.0 * setup + bytes_to_cycles(u0.1) >= u1.0 * setup + bytes_to_cycles(u1.1) {
                u0
            } else {
                u1
            }
        }
        BalancePolicy::Greedy { .. } => {
            // Cross-layer byte memory: Greedy assigns whole streams
            // against byte counters that persist across layers, so the
            // heaviest unit leads the perfect split by about half the
            // largest single stream rather than landing exactly on it.
            let max_stream_words = rows_list
                .iter()
                .map(|&r| strip_words(r).div_ceil(pieces(r)) as u64)
                .max()
                .unwrap_or(0)
                .max(g.kernel_words as u64)
                .max(bias_words)
                .max(cfg.icache_bank_instrs as u64 * 2);
            (
                streams.div_ceil(units),
                loads_bytes.div_ceil(units) + max_stream_words * wb / 2,
            )
        }
    };
    let per_unit_cycles = worst_unit_streams * setup + bytes_to_cycles(worst_unit_bytes);
    let dma_cycles = bus_cycles.max(per_unit_cycles);

    // ---- startup: serial prefix before the first window --------------
    let (start_words, start_streams) = match order {
        LoopOrder::Kloop => (
            n_cus * strip_words(rows_list[0]) as u64 + group_words,
            n_cus * pieces(rows_list[0]) as u64 + 4,
        ),
        // Mloop stages every resident strip before compute.
        LoopOrder::Mloop => (maps_once + group_words, maps_streams_once + 4),
        // Rotation stages only the first `mbuf_banks − 1` strips plus
        // kernel set 0 — the startup edge over the resident skeleton.
        LoopOrder::MloopRot => {
            let lead = (cfg.mbuf_banks as u64 - 1).min(n_tiles);
            (
                lead * n_cus * strip_words(rows_list[0]) as u64 + gset as u64 * group_words,
                lead * n_cus * pieces(rows_list[0]) as u64 + gset as u64 * 4,
            )
        }
    };
    let startup_cycles =
        30 + start_streams.div_ceil(units) * setup + bytes_to_cycles(start_words * wb);

    let cycles = startup_cycles + compute_cycles.max(issue_cycles).max(dma_cycles) + 150;
    CostEstimate {
        cycles,
        dram_bytes,
        compute_cycles,
        issue_cycles,
        dma_cycles,
        startup_cycles,
        streams,
    }
}

/// The seed heuristic schedule: capacity-maximal tile height, the
/// global balance policy, and the Kloop skeleton — the only one the
/// seed codegen ever emitted (its §6.2 two-way traffic compare was an
/// annotation codegen never consumed; that analysis is preserved in
/// `decide::required_bandwidth_gbs` / Figure 4). `TuneMode::Heuristic`
/// therefore reproduces seed *emission* bit-for-bit.
pub fn seed_heuristic(g: &ConvGeom, _cfg: &SnowflakeConfig, opts: &CompileOptions) -> Schedule {
    Schedule {
        order: LoopOrder::Kloop,
        rows_per_cu: g.max_rows.max(1),
        policy: opts.balance,
    }
}

/// Bounded tile-height candidate set (see the module docs).
fn rows_candidates(g: &ConvGeom, n_cus: usize) -> Vec<usize> {
    let cap = g.max_rows.max(1);
    let mut set = std::collections::BTreeSet::new();
    for r in 1..=cap.min(8) {
        set.insert(r);
    }
    set.insert(cap);
    if cap > 1 {
        set.insert(cap - 1);
    }
    for t in 1..=4usize {
        let r = g.h_out.div_ceil(n_cus * t);
        if (1..=cap).contains(&r) {
            set.insert(r);
        }
    }
    set.into_iter().collect()
}

/// Balance-policy candidates: the Greedy split spectrum, or the pinned
/// non-Greedy base policy.
fn policy_candidates(base: BalancePolicy) -> Vec<BalancePolicy> {
    match base {
        BalancePolicy::Greedy { split } => {
            let mut splits = vec![1usize, 2, 4, 8];
            if !splits.contains(&split.max(1)) {
                splits.push(split.max(1));
                splits.sort_unstable();
            }
            splits.into_iter().map(|s| BalancePolicy::Greedy { split: s }).collect()
        }
        other => vec![other],
    }
}

/// Every candidate schedule for the layer (valid by construction).
pub fn candidates(g: &ConvGeom, cfg: &SnowflakeConfig, base: BalancePolicy) -> Vec<Schedule> {
    let mut out = Vec::new();
    for rows in rows_candidates(g, cfg.n_cus) {
        for policy in policy_candidates(base) {
            out.push(Schedule { order: LoopOrder::Kloop, rows_per_cu: rows, policy });
            if mloop_viable(g, cfg, rows) {
                out.push(Schedule { order: LoopOrder::Mloop, rows_per_cu: rows, policy });
            }
            let split = Schedule { order: LoopOrder::MloopRot, rows_per_cu: rows, policy }.split();
            if mloop_rot_viable(g, cfg, rows, split) {
                out.push(Schedule { order: LoopOrder::MloopRot, rows_per_cu: rows, policy });
            }
        }
    }
    out
}

/// All candidates ranked by predicted cycles (then bytes) — the measured
/// tuner's top-K source. The seed heuristic's schedule is always
/// included.
pub fn ranked(
    g: &ConvGeom,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> Vec<(Schedule, CostEstimate)> {
    let mut all: Vec<(Schedule, CostEstimate)> = Vec::new();
    let push = |s: Schedule, all: &mut Vec<(Schedule, CostEstimate)>| {
        if !all.iter().any(|(q, _)| *q == s) {
            // Rank delay-slot-agnostically (see `search`).
            all.push((s, estimate(g, &s, cfg, false)));
        }
    };
    push(seed_heuristic(g, cfg, opts), &mut all);
    for s in candidates(g, cfg, opts.balance) {
        push(s, &mut all);
    }
    all.sort_by_key(|(_, e)| (e.cycles, e.dram_bytes));
    all
}

/// Argmin of the analytical model over the candidate space, with
/// hysteresis: the winner must beat the seed heuristic's predicted
/// cycles by [`DISPLACE_MARGIN_PCT`] percent, otherwise the seed's
/// schedule is kept (sub-margin deltas are model noise). A
/// `force_loop_order` in `opts` restricts the space to schedules that
/// genuinely emit that order (falling back to Kloop candidates when no
/// viable Mloop schedule exists for the layer) and disables the seed
/// hysteresis when the seed's order is excluded.
pub fn search(
    g: &ConvGeom,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> (Schedule, CostEstimate) {
    // Rank with plain delay slots regardless of `smart_delay_slots`:
    // hand and auto compiles then pick identical schedules, so smart
    // mode only ever shortens the same program (the Table 1 invariant).
    let smart = false;
    let mut cands = candidates(g, cfg, opts.balance);
    match opts.force_loop_order {
        Some(LoopOrder::Kloop) => cands.retain(|s| s.order == LoopOrder::Kloop),
        // Forcing Mloop means the Mloop *family*: resident or rotation,
        // whichever candidates exist for the layer.
        Some(LoopOrder::Mloop) if cands.iter().any(|s| s.order != LoopOrder::Kloop) => {
            cands.retain(|s| s.order != LoopOrder::Kloop)
        }
        Some(LoopOrder::MloopRot) if cands.iter().any(|s| s.order == LoopOrder::MloopRot) => {
            cands.retain(|s| s.order == LoopOrder::MloopRot)
        }
        _ => {}
    }
    let seed = seed_heuristic(g, cfg, opts);
    let seed_eligible = match opts.force_loop_order {
        None => true,
        Some(o) => o == seed.order,
    };
    let mut best_s = if seed_eligible {
        seed
    } else {
        // Forced away from the seed's order: start from the first
        // filtered candidate instead.
        cands.first().copied().unwrap_or(seed)
    };
    let mut best_e = estimate(g, &best_s, cfg, smart);
    let seed_e = if best_s == seed { best_e } else { estimate(g, &seed, cfg, smart) };
    for s in cands {
        if s == best_s {
            continue;
        }
        let e = estimate(g, &s, cfg, smart);
        if e.cycles < best_e.cycles
            || (e.cycles == best_e.cycles && e.dram_bytes < best_e.dram_bytes)
        {
            best_s = s;
            best_e = e;
        }
    }
    if seed_eligible
        && best_s != seed
        && best_e.cycles.saturating_mul(100) >= seed_e.cycles.saturating_mul(100 - DISPLACE_MARGIN_PCT)
    {
        return (seed, seed_e);
    }
    (best_s, best_e)
}

// ---------------------------------------------------------------------
// Pool schedules (ROADMAP follow-on from ISSUE 2)
// ---------------------------------------------------------------------

/// Maxpool geometry the pool cost model needs — everything `decide`
/// derives before choosing the strip height. Pool strips share the
/// conv maps' startup-vs-volume trade: taller strips mean fewer tiles
/// (fewer DMA streams, less per-tile loop overhead) but a longer
/// serial prefix before the first MAX can issue.
#[derive(Clone, Copy, Debug)]
pub struct PoolGeom {
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub h_out: usize,
    pub w_out: usize,
    /// Real (unpadded) channels — the per-row write volume and the
    /// channel-loop trip count.
    pub c: usize,
    pub c_pad: usize,
    /// Input canvas row words (margin/slack inclusive).
    pub row_words_in: usize,
    /// Strip spill rows (lane overreach past the last window).
    pub spill: usize,
    /// Constraint cap on `rows_per_cu` (MBuf bank, `h_out/n_cus`).
    pub max_rows: usize,
}

/// Predict cycles/traffic for a maxpool layer at one strip height.
/// Mirrors `codegen/pool.rs::emit_maxpool`: per tile, each CU streams
/// one strip and then issues `rows × c × x_groups × kh·kw` MAX ops
/// (1 cycle each on the pool unit), with the channel/row loop overhead
/// on the issue stage. Same shape as the conv estimate:
/// `startup + max(compute, issue, dma) + drain`.
pub fn pool_estimate(
    g: &PoolGeom,
    rows_per_cu: usize,
    split: usize,
    cfg: &SnowflakeConfig,
) -> CostEstimate {
    let n_cus = cfg.n_cus as u64;
    let units = cfg.n_load_units as u64;
    let setup = cfg.dma_setup_cycles;
    let wb = cfg.word_bytes as u64;
    let budget_mb = (cfg.axi_bytes_per_cycle * 1000.0).round().max(1.0) as u64;
    let bytes_to_cycles = |bytes: u64| (bytes * 1000).div_ceil(budget_mb);

    let rows_list = tile_rows(g.h_out, rows_per_cu, cfg.n_cus);
    let n_tiles = rows_list.len() as u64;
    let strip_words = |r: usize| ((r - 1) * g.stride + g.kh + g.spill) * g.row_words_in;
    let pieces = |r: usize| strip_pieces(strip_words(r), split);

    // ---- traffic -----------------------------------------------------
    let maps_once: u64 = rows_list.iter().map(|&r| n_cus * strip_words(r) as u64).sum();
    let streams: u64 = rows_list.iter().map(|&r| n_cus * pieces(r) as u64).sum();
    let windows_rows: u64 = rows_list.iter().map(|&r| r as u64).sum();
    let stores_words = windows_rows * n_cus * (g.c * g.w_out) as u64;
    let dram_bytes = (maps_once + stores_words) * wb;

    // ---- compute (pool unit, 1 cycle per MAX) ------------------------
    let x_groups = g.w_out.div_ceil(16) as u64;
    let taps = (g.kh * g.kw) as u64;
    let compute_cycles = windows_rows * g.c as u64 * x_groups * taps;

    // ---- issue -------------------------------------------------------
    // Per x-group: 2 address adds + taps MAXes + taps-1 advances; per
    // channel iteration ~8 loop-control instructions (branch + delay
    // slots + the two +1 walks); per row ~13; per tile ~10 + the
    // next-tile strip loads (5 instrs per stream).
    let per_group = 1 + 2 * taps;
    let per_chan = x_groups * per_group + 8;
    let per_row = g.c as u64 * per_chan + 13;
    let issue_cycles = windows_rows * per_row + n_tiles * 10 + streams * 5;

    // ---- DMA ---------------------------------------------------------
    let bus_cycles = bytes_to_cycles(maps_once * wb);
    let per_unit_cycles = streams.div_ceil(units) * setup + bytes_to_cycles(maps_once * wb / units.max(1));
    let dma_cycles = bus_cycles.max(per_unit_cycles);

    // ---- startup: tile-0 strips before the first MAX -----------------
    let start_streams = n_cus * pieces(rows_list[0]) as u64;
    let start_bytes = n_cus * strip_words(rows_list[0]) as u64 * wb;
    let startup_cycles = 20 + start_streams.div_ceil(units) * setup + bytes_to_cycles(start_bytes);

    let cycles = startup_cycles + compute_cycles.max(issue_cycles).max(dma_cycles) + 150;
    CostEstimate {
        cycles,
        dram_bytes,
        compute_cycles,
        issue_cycles,
        dma_cycles,
        startup_cycles,
        streams,
    }
}

/// The maps-split factor pool strip loads inherit from the base
/// balance policy (pool layers have no per-layer policy knob).
pub fn pool_split(opts: &CompileOptions) -> usize {
    match opts.balance {
        BalancePolicy::Greedy { split } => split.max(1),
        _ => 1,
    }
}

/// Argmin of [`pool_estimate`] over the strip-height candidates, with
/// the same hysteresis as the conv search: the seed heuristic
/// (capacity-maximal `max_rows`) is kept unless a candidate beats its
/// prediction by [`DISPLACE_MARGIN_PCT`] percent. Candidate set mirrors
/// [`rows_candidates`]: small heights, the cap and cap−1, and heights
/// giving exactly 1..=4 tiles.
pub fn pool_search(
    g: &PoolGeom,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> (usize, CostEstimate) {
    let split = pool_split(opts);
    let seed = g.max_rows.max(1);
    let seed_e = pool_estimate(g, seed, split, cfg);
    let mut cands = std::collections::BTreeSet::new();
    for r in 1..=seed.min(8) {
        cands.insert(r);
    }
    cands.insert(seed);
    if seed > 1 {
        cands.insert(seed - 1);
    }
    for t in 1..=4usize {
        let r = g.h_out.div_ceil(cfg.n_cus * t);
        if (1..=seed).contains(&r) {
            cands.insert(r);
        }
    }
    let (mut best_r, mut best_e) = (seed, seed_e);
    for r in cands {
        if r == best_r {
            continue;
        }
        let e = pool_estimate(g, r, split, cfg);
        if e.cycles < best_e.cycles
            || (e.cycles == best_e.cycles && e.dram_bytes < best_e.dram_bytes)
        {
            best_r = r;
            best_e = e;
        }
    }
    if best_r != seed
        && best_e.cycles.saturating_mul(100)
            >= seed_e.cycles.saturating_mul(100 - DISPLACE_MARGIN_PCT)
    {
        return (seed, seed_e);
    }
    (best_r, best_e)
}

/// Check an explicit override against the layer's constraint caps. An
/// explicitly requested Mloop that the skeleton cannot emit is an
/// error, not a silent Kloop fallback — only `force_loop_order` (a
/// whole-model knob) degrades gracefully.
pub fn validate(s: &Schedule, g: &ConvGeom, cfg: &SnowflakeConfig) -> Result<(), String> {
    if s.rows_per_cu < 1 || s.rows_per_cu > g.max_rows {
        return Err(format!(
            "schedule rows_per_cu {} outside 1..={} for this layer",
            s.rows_per_cu, g.max_rows
        ));
    }
    if s.order == LoopOrder::Mloop && !mloop_viable(g, cfg, s.rows_per_cu) {
        return Err(format!(
            "explicit Mloop schedule is not emittable for this layer at rows_per_cu {} \
             (needs 2..={} resident map tiles, no fused bypass, and the unrolled block \
             within an icache bank)",
            s.rows_per_cu, cfg.mbuf_banks
        ));
    }
    if s.order == LoopOrder::MloopRot && !mloop_rot_viable(g, cfg, s.rows_per_cu, s.split()) {
        return Err(format!(
            "explicit Mloop-rotation schedule is not emittable for this layer at \
             rows_per_cu {} / split {} (needs >=2 map tiles, >=2 MBuf banks, no fused \
             bypass, the kernel group inside a WBuf region, and each pass block within \
             an icache bank)",
            s.rows_per_cu,
            s.split()
        ));
    }
    if s.split() > 64 {
        return Err(format!("schedule split {} unreasonably large (max 64)", s.split()));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Serving capacity model (ISSUE 7)
// ---------------------------------------------------------------------------

/// The serving-side analogue of the per-layer cost model: given each
/// registered model's service time in cycles (cost-model predicted or
/// calibrated by one measured inference — sim timing is
/// input-independent, so one sample is exact) and the worker count, it
/// answers the questions the admission controller and the capacity
/// planner ask:
///
/// * [`ServeModel::completion`] — when would a request admitted *now*
///   finish, given the backlog already committed? This is the deadline
///   predicate behind `ServeError::Shed`.
/// * [`ServeModel::roofline_rps`] — the saturation throughput for a
///   given popularity mix; capacity sweeps are expressed as multiples
///   of it.
///
/// The backlog estimate deliberately ignores batching and WFQ order:
/// total committed cycles divided evenly over the workers is a
/// scheduling-independent lower bound that is exact for a saturated
/// pool, which is the only regime where admission control matters.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeModel {
    /// Per registered model: cycles one inference costs.
    pub service_cycles: Vec<u64>,
    /// Virtual (or real) worker count serving in parallel.
    pub workers: usize,
}

impl ServeModel {
    pub fn new(service_cycles: Vec<u64>, workers: usize) -> ServeModel {
        ServeModel { service_cycles, workers: workers.max(1) }
    }

    /// Cycles until a backlog of `backlog_cycles` committed work
    /// drains, with the workers pulling in parallel.
    pub fn drain_cycles(&self, backlog_cycles: u64) -> u64 {
        backlog_cycles.div_ceil(self.workers as u64)
    }

    /// Predicted completion time (absolute, in cycles) of a `model`
    /// request admitted at `now` behind `backlog_cycles` of committed
    /// work.
    pub fn completion(&self, now: u64, backlog_cycles: u64, model: usize) -> u64 {
        now + self.drain_cycles(backlog_cycles) + self.service_cycles[model]
    }

    /// Mean service cycles per request under a popularity `mix`
    /// (probabilities per model, summing to 1).
    pub fn mean_service_cycles(&self, mix: &[f64]) -> f64 {
        assert_eq!(mix.len(), self.service_cycles.len(), "mix/model count mismatch");
        mix.iter().zip(&self.service_cycles).map(|(p, c)| p * *c as f64).sum()
    }

    /// Saturation throughput in requests per second of virtual time:
    /// `workers / mean service time`. Offered load above this must
    /// queue without bound; admission control exists to shed it.
    pub fn roofline_rps(&self, mix: &[f64], clock_mhz: f64) -> f64 {
        let mean = self.mean_service_cycles(mix);
        if mean <= 0.0 {
            return 0.0;
        }
        self.workers as f64 * clock_mhz * 1e6 / mean
    }
}

#[cfg(test)]
mod serve_model_tests {
    use super::ServeModel;

    #[test]
    fn completion_accounts_for_backlog_and_workers() {
        let m = ServeModel::new(vec![1000, 4000], 2);
        // Empty backlog: now + own service time.
        assert_eq!(m.completion(500, 0, 0), 1500);
        // 6000 committed cycles over 2 workers = 3000 to drain.
        assert_eq!(m.drain_cycles(6000), 3000);
        assert_eq!(m.completion(0, 6000, 1), 7000);
        // Odd backlogs round up (a worker cannot serve half a request).
        assert_eq!(m.drain_cycles(5), 3);
    }

    #[test]
    fn roofline_scales_with_workers_and_mix() {
        let m = ServeModel::new(vec![250_000, 1_000_000], 1);
        // Uniform mix: mean 625k cycles at 250 MHz = 2.5 ms => 400 rps.
        let r1 = m.roofline_rps(&[0.5, 0.5], 250.0);
        assert!((r1 - 400.0).abs() < 1e-6, "{r1}");
        let m4 = ServeModel::new(vec![250_000, 1_000_000], 4);
        assert!((m4.roofline_rps(&[0.5, 0.5], 250.0) - 1600.0).abs() < 1e-6);
        // A mix leaning on the fast model raises the roofline.
        assert!(m.roofline_rps(&[1.0, 0.0], 250.0) > r1);
    }

    #[test]
    fn workers_are_clamped_to_one() {
        let m = ServeModel::new(vec![100], 0);
        assert_eq!(m.workers, 1);
        assert_eq!(m.drain_cycles(100), 100);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// AlexNet-conv2-class geometry (27x27, 5x5, 64 -> 192).
    fn conv2_geom() -> ConvGeom {
        ConvGeom {
            kh: 5,
            stride: 1,
            h_out: 27,
            w_out: 27,
            row_words_in: (27 + 2 * 2) * 64,
            row_read: 320,
            n_segs: 1,
            kernel_words: 5 * 320,
            k_groups: 48,
            c_pad_out: 192,
            has_bypass: false,
            byp_row_words: 0,
            max_rows: 6,
            dbuf_w: true,
        }
    }

    #[test]
    fn candidate_space_is_bounded_and_contains_heuristic() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let opts = CompileOptions::default();
        let cands = candidates(&g, &cfg, opts.balance);
        assert!(!cands.is_empty());
        assert!(cands.len() <= 128, "unbounded candidate space: {}", cands.len());
        let h = seed_heuristic(&g, &cfg, &opts);
        assert!(
            cands.iter().any(|s| *s == h),
            "heuristic schedule {h:?} missing from the candidate space"
        );
        for s in &cands {
            assert!(validate(s, &g, &cfg).is_ok(), "{s:?}");
            assert!((1..=g.max_rows).contains(&s.rows_per_cu));
        }
        // The seed reproduces the seed codegen: Kloop, capacity rows.
        assert_eq!(h.order, LoopOrder::Kloop);
        assert_eq!(h.rows_per_cu, g.max_rows);
    }

    #[test]
    fn mloop_cuts_kernel_traffic_on_two_tile_layers() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom(); // max_rows 6 -> tiles [6, 1]
        assert!(mloop_viable(&g, &cfg, 6));
        let pol = BalancePolicy::Greedy { split: 2 };
        let k = estimate(
            &g,
            &Schedule { order: LoopOrder::Kloop, rows_per_cu: 6, policy: pol },
            &cfg,
            false,
        );
        let m = estimate(
            &g,
            &Schedule { order: LoopOrder::Mloop, rows_per_cu: 6, policy: pol },
            &cfg,
            false,
        );
        assert!(m.dram_bytes < k.dram_bytes, "mloop {} !< kloop {}", m.dram_bytes, k.dram_bytes);
        // Same compute either way (identical window work).
        assert_eq!(m.compute_cycles, k.compute_cycles);
    }

    #[test]
    fn mloop_unavailable_with_bypass_or_single_tile() {
        let cfg = SnowflakeConfig::default();
        let mut g = conv2_geom();
        g.has_bypass = true;
        g.byp_row_words = 31 * 192;
        assert!(!mloop_viable(&g, &cfg, 6));
        let mut g1 = conv2_geom();
        g1.h_out = 24; // 6 rows x 4 CUs: one tile
        assert!(!mloop_viable(&g1, &cfg, 6));
        assert!(!mloop_rot_viable(&g1, &cfg, 6, 2), "single tile: nothing to rotate");
        assert_eq!(
            effective_order(&g1, &cfg, LoopOrder::Mloop, 6, 2),
            LoopOrder::Kloop,
            "single-tile Mloop must clamp to the (identical) Kloop skeleton"
        );
        assert_eq!(effective_order(&g1, &cfg, LoopOrder::MloopRot, 6, 2), LoopOrder::Kloop);
    }

    #[test]
    fn search_is_deterministic_and_never_worse_than_heuristic() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let opts = CompileOptions::default();
        let (s1, e1) = search(&g, &cfg, &opts);
        let (s2, e2) = search(&g, &cfg, &opts);
        assert_eq!(s1, s2);
        assert_eq!(e1, e2);
        let h = seed_heuristic(&g, &cfg, &opts);
        let he = estimate(&g, &h, &cfg, false);
        assert!(e1.cycles <= he.cycles, "search {e1:?} worse than heuristic {he:?}");
    }

    #[test]
    fn split_trades_setup_for_balance() {
        // More pieces -> more streams -> more predicted setup cost.
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let e1 = estimate(
            &g,
            &Schedule {
                order: LoopOrder::Kloop,
                rows_per_cu: 6,
                policy: BalancePolicy::Greedy { split: 1 },
            },
            &cfg,
            false,
        );
        let e8 = estimate(
            &g,
            &Schedule {
                order: LoopOrder::Kloop,
                rows_per_cu: 6,
                policy: BalancePolicy::Greedy { split: 8 },
            },
            &cfg,
            false,
        );
        assert!(e8.streams > e1.streams);
        assert_eq!(e8.dram_bytes, e1.dram_bytes, "split must not change traffic volume");
    }

    #[test]
    fn one_unit_predicts_slower_dma_than_greedy() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let gr = estimate(
            &g,
            &Schedule {
                order: LoopOrder::Kloop,
                rows_per_cu: 6,
                policy: BalancePolicy::Greedy { split: 2 },
            },
            &cfg,
            false,
        );
        let one = estimate(
            &g,
            &Schedule { order: LoopOrder::Kloop, rows_per_cu: 6, policy: BalancePolicy::OneUnit },
            &cfg,
            false,
        );
        assert!(one.dma_cycles >= gr.dma_cycles);
    }

    #[test]
    fn validate_rejects_out_of_cap_rows_and_unemittable_mloop() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let bad = Schedule {
            order: LoopOrder::Kloop,
            rows_per_cu: g.max_rows + 1,
            policy: BalancePolicy::default(),
        };
        assert!(validate(&bad, &g, &cfg).is_err());
        let ok = Schedule { rows_per_cu: 1, ..bad };
        assert!(validate(&ok, &g, &cfg).is_ok());
        // rows 1 -> 7 tiles: an explicit Mloop request must error, not
        // silently fall back to Kloop.
        let mloop_bad = Schedule { order: LoopOrder::Mloop, ..ok };
        assert!(validate(&mloop_bad, &g, &cfg).is_err());
        let mloop_ok = Schedule { order: LoopOrder::Mloop, rows_per_cu: 6, ..ok };
        assert!(validate(&mloop_ok, &g, &cfg).is_ok());
    }

    /// AlexNet-conv1-class geometry (224x224x3 -> 55x55x64, 11x11/4):
    /// 3 map tiles at the capacity height — more tiles than banks, so
    /// only the rotation skeleton can keep the kernel stream resident.
    fn conv1_geom() -> ConvGeom {
        ConvGeom {
            kh: 11,
            stride: 4,
            h_out: 55,
            w_out: 55,
            row_words_in: (224 + 2 * 2) * 4,
            row_read: 48,
            n_segs: 1,
            kernel_words: 11 * 48,
            k_groups: 16,
            c_pad_out: 64,
            has_bypass: false,
            byp_row_words: 0,
            max_rows: 6,
            dbuf_w: true,
        }
    }

    /// The rotation acceptance scenario's board: a 64 KB WBuf (all 16
    /// conv1 groups in one region — a single pass) on a 1.4 B/cycle bus.
    fn starved_cfg() -> SnowflakeConfig {
        SnowflakeConfig {
            wbuf_bytes: 64 * 1024,
            axi_bytes_per_cycle: 1.4,
            ..SnowflakeConfig::default()
        }
    }

    #[test]
    fn rot_sets_partition_the_groups() {
        let cfg = SnowflakeConfig::default(); // region 4096 words
        assert_eq!(rot_sets(528, 16, &cfg), (7, 3)); // conv1 at 16 KB WBuf
        assert_eq!(rot_sets(4096, 16, &cfg), (1, 16)); // region-filling kernels
        assert_eq!(rot_sets(224, 16, &cfg), (16, 1)); // everything resident
        let big = starved_cfg(); // region 16384 words
        assert_eq!(rot_sets(528, 16, &big), (16, 1));
        // A set never exceeds the region: per * kernel_words <= region.
        for kw in [100, 528, 1600, 3456] {
            let (per, passes) = rot_sets(kw, 48, &cfg);
            assert!(per * kw <= cfg.wbuf_region_words(), "kw {kw}");
            assert!(per * passes >= 48, "sets must cover all groups (kw {kw})");
        }
    }

    #[test]
    fn rotation_viable_exactly_beyond_the_bank_count() {
        let cfg = SnowflakeConfig::default();
        let g = conv1_geom();
        // 3 tiles at the capacity height: resident Mloop impossible,
        // rotation viable (split 1 keeps the pass block in budget).
        assert!(!mloop_viable(&g, &cfg, 6));
        assert!(mloop_rot_viable(&g, &cfg, 6, 1));
        assert_eq!(effective_order(&g, &cfg, LoopOrder::Mloop, 6, 1), LoopOrder::MloopRot);
        assert_eq!(effective_order(&g, &cfg, LoopOrder::MloopRot, 6, 1), LoopOrder::MloopRot);
        // Tall splits inflate the unrolled prefetch code past the bank.
        assert!(!mloop_rot_viable(&g, &cfg, 6, 8));
        // Many tiny tiles overflow the pass block too.
        assert!(!mloop_rot_viable(&g, &cfg, 1, 1));
        // Bypass excludes the whole Mloop family.
        let mut gb = g;
        gb.has_bypass = true;
        assert!(!mloop_rot_viable(&gb, &cfg, 6, 1));
        // A kernel too big for one WBuf region cannot hold a set.
        let mut gk = g;
        gk.dbuf_w = false;
        assert!(!mloop_rot_viable(&gk, &cfg, 6, 1));
    }

    #[test]
    fn rotation_estimate_reads_kernels_once_and_maps_per_pass() {
        let cfg = SnowflakeConfig::default(); // 16 KB WBuf: 3 passes
        let g = conv1_geom();
        let pol = BalancePolicy::Greedy { split: 1 };
        let rot = estimate(
            &g,
            &Schedule { order: LoopOrder::MloopRot, rows_per_cu: 6, policy: pol },
            &cfg,
            false,
        );
        let k = estimate(
            &g,
            &Schedule { order: LoopOrder::Kloop, rows_per_cu: 6, policy: pol },
            &cfg,
            false,
        );
        // Same compute either way; rotation re-reads maps x passes but
        // reads kernels once, so at 3 passes it moves *more* bytes here.
        assert_eq!(rot.compute_cycles, k.compute_cycles);
        let (_, passes) = rot_sets(g.kernel_words, g.k_groups, &cfg);
        assert_eq!(passes, 3);
        assert!(rot.dram_bytes > k.dram_bytes, "3-pass rotation should lose on this board");
    }

    #[test]
    fn rotation_wins_search_on_the_starved_board() {
        // The acceptance crossover: single-pass rotation strictly
        // undercuts Kloop's per-tile kernel re-streaming, and with the
        // layer DMA-bound the search must pick it.
        let cfg = starved_cfg();
        let g = conv1_geom();
        let pol = BalancePolicy::Greedy { split: 1 };
        let rot = estimate(
            &g,
            &Schedule { order: LoopOrder::MloopRot, rows_per_cu: 6, policy: pol },
            &cfg,
            false,
        );
        let k = estimate(
            &g,
            &Schedule { order: LoopOrder::Kloop, rows_per_cu: 6, policy: pol },
            &cfg,
            false,
        );
        assert!(rot.dram_bytes < k.dram_bytes, "rot {} !< kloop {}", rot.dram_bytes, k.dram_bytes);
        assert!(rot.cycles < k.cycles, "rot {} !< kloop {}", rot.cycles, k.cycles);
        let (s, _) = search(&g, &cfg, &CompileOptions::default());
        assert_eq!(s.order, LoopOrder::MloopRot, "search kept {s:?}");
        assert!(validate(&s, &g, &cfg).is_ok());
    }

    #[test]
    fn validate_rejects_unemittable_rotation() {
        let cfg = SnowflakeConfig::default();
        let g = conv1_geom();
        let bad = Schedule {
            order: LoopOrder::MloopRot,
            rows_per_cu: 1, // 14 tiles: pass block far beyond the bank
            policy: BalancePolicy::Greedy { split: 1 },
        };
        let err = validate(&bad, &g, &cfg).unwrap_err();
        assert!(err.contains("not emittable"), "{err}");
        let ok = Schedule { rows_per_cu: 6, ..bad };
        assert!(validate(&ok, &g, &cfg).is_ok());
    }

    /// AlexNet-pool1-class geometry (55x55 -> 27x27, 3x3 stride 2).
    fn pool1_geom() -> PoolGeom {
        PoolGeom {
            kh: 3,
            kw: 3,
            stride: 2,
            h_out: 27,
            w_out: 27,
            c: 64,
            c_pad: 64,
            row_words_in: 55 * 64,
            spill: 1,
            max_rows: 4,
        }
    }

    #[test]
    fn pool_estimate_tracks_the_real_tradeoffs() {
        let cfg = SnowflakeConfig::default();
        let g = pool1_geom();
        let tall = pool_estimate(&g, g.max_rows, 2, &cfg);
        let short = pool_estimate(&g, 1, 2, &cfg);
        assert!(tall.cycles > 0 && short.cycles > 0);
        // Shorter strips mean more tiles, hence more DMA streams and a
        // smaller serial startup prefix.
        assert!(short.streams > tall.streams);
        assert!(short.startup_cycles < tall.startup_cycles);
        // Compute volume is height-independent (same windows either way).
        assert_eq!(short.compute_cycles, tall.compute_cycles);
        // Shorter strips re-stream more window-overlap rows.
        assert!(short.dram_bytes >= tall.dram_bytes);
    }

    #[test]
    fn pool_search_keeps_seed_on_ties_and_stays_in_cap() {
        let cfg = SnowflakeConfig::default();
        let g = pool1_geom();
        let opts = CompileOptions::default();
        let (rows, e) = pool_search(&g, &cfg, &opts);
        assert!((1..=g.max_rows).contains(&rows));
        let seed_e = pool_estimate(&g, g.max_rows, pool_split(&opts), &cfg);
        assert!(e.cycles <= seed_e.cycles, "search result predicted worse than the seed");
        // A candidate inside the hysteresis margin must not displace the
        // seed: search on a single-candidate space returns the seed.
        let tiny = PoolGeom { max_rows: 1, h_out: 4, ..g };
        let (r1, _) = pool_search(&tiny, &cfg, &opts);
        assert_eq!(r1, 1);
    }
}
