//! Analytical schedule cost model and per-layer schedule search.
//!
//! The seed compiler decided conv schedules with a fixed heuristic:
//! maximize `rows_per_cu` to buffer capacity and compare two closed-form
//! traffic numbers for the loop order (§6.2), with the maps-split factor
//! and balance policy fixed globally. This module replaces that with
//! design-space exploration over an analytical performance model, the
//! way the related FPGA compilation flows (fpgaConvNet, the DPU flow)
//! pick per-layer configurations: every candidate schedule — loop order
//! × tile height × maps-split factor × balance policy — gets a
//! predicted cycle count and off-chip byte count, and the compiler keeps
//! the argmin.
//!
//! ## What the model models
//!
//! * **CU occupancy**: every vector MAC is broadcast to all CUs, so the
//!   per-CU serial work is `k_groups × Σ_tile rows × w_out` windows of
//!   `kh·row_read/16 + gather` cycles — including the *redundant* rows a
//!   back-shifted tail tile recomputes.
//! * **Issue bandwidth**: one instruction per cycle; per-window MAC +
//!   trace-advance instructions plus loop-control overhead (branch delay
//!   slots are 4 no-ops in plain mode, folded tails in smart mode).
//! * **DMA**: total off-chip bytes at the fair-shared AXI budget, plus
//!   per-unit serialization — a unit pays `dma_setup_cycles` per stream
//!   before transferring, so stream count (the split factor) and the
//!   unit distribution (the balance policy) both matter. The per-unit
//!   distribution is approximated per policy: even for Greedy, split by
//!   class for TwoUnits, everything on unit 0 for OneUnit.
//! * **Startup**: the serial prefix before the first window can run —
//!   tile-0 map strips (all resident strips for Mloop) and kernel
//!   group 0.
//!
//! The layer estimate is `startup + max(compute, issue, dma) + drain`:
//! double-buffered prefetch overlaps steady-state phases, so the slowest
//! resource governs. **Deliberately ignored**: icache reload stalls,
//! RAW/queue-depth issue stalls, scoreboard wait tails at tile
//! boundaries, and DMA quota re-sharing as streams come and go. The
//! documented error bound is a factor of `MODEL_ERROR_BOUND` per conv
//! layer versus the event core (typically well inside ±30%;
//! `benches/tuning.rs` asserts the bound per layer).
//!
//! ## The candidate space
//!
//! * loop order: Kloop always; Mloop only where the maps-resident
//!   skeleton exists (no fused bypass, `2 ≤ n_tiles ≤ mbuf_banks`, the
//!   unrolled tile loop fits an icache bank block).
//! * `rows_per_cu`: 1..=8, the capacity cap and cap−1, and the heights
//!   that give exactly 1..=4 tiles — a bounded, deduplicated set.
//! * maps split: {1, 2, 4, 8} (∪ the user's split) under Greedy.
//! * balance policy: the Greedy family; a non-Greedy base policy pins
//!   every candidate to it so Table-3-style experiments stay meaningful.
//!
//! Ties keep the seed heuristic's schedule, and a candidate must beat
//! the seed's prediction by [`DISPLACE_MARGIN_PCT`] percent to displace
//! it, so tuned output only deviates where the model predicts a real
//! win (e.g. the Mloop flip on kernel-dominated two-tile layers, worth
//! ~10% cycles and ~2x traffic on ResNet18's layer-4 convs).

use super::decide::CONV_SPILL_ROWS;
use super::{BalancePolicy, CompileOptions, LoopOrder};
use crate::arch::SnowflakeConfig;
use crate::compiler::tile::tile_rows;

/// Instruction budget for the Mloop single-block skeleton: the icache
/// bank minus the reload-prologue slots and headroom for estimate
/// error (72 = 8 prologue slots + 64 estimate margin; 440 on the
/// default 512-instruction bank). Scales with retargeted configs.
fn mloop_block_budget(cfg: &SnowflakeConfig) -> usize {
    cfg.icache_bank_instrs.saturating_sub(72)
}

/// Documented worst-case ratio between predicted and event-core
/// measured cycles per conv layer (either direction). Asserted by
/// `benches/tuning.rs`.
pub const MODEL_ERROR_BOUND: f64 = 3.0;

/// Minimum predicted improvement (percent) before the search displaces
/// the seed heuristic's schedule. Sub-threshold deltas are inside the
/// model's noise floor, and honoring them would churn schedules (e.g.
/// shaving tile heights for a 0.5% predicted startup win while
/// multiplying kernel re-streams); with the margin, tuned output
/// deviates from the seed only where the model predicts a real win.
pub const DISPLACE_MARGIN_PCT: u64 = 2;

/// One candidate conv schedule: the §6.2 loop order, the map-tile
/// height, and the LD balance policy (whose Greedy split factor is the
/// §6.3 maps-split knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Schedule {
    pub order: LoopOrder,
    pub rows_per_cu: usize,
    pub policy: BalancePolicy,
}

impl Schedule {
    /// Pieces each per-CU maps strip load is split into.
    pub fn split(&self) -> usize {
        match self.policy {
            BalancePolicy::Greedy { split } => split.max(1),
            _ => 1,
        }
    }
}

/// Conv geometry the model needs — everything `decide` derives before
/// schedule selection, independent of the schedule itself.
#[derive(Clone, Copy, Debug)]
pub struct ConvGeom {
    pub kh: usize,
    pub stride: usize,
    pub h_out: usize,
    pub w_out: usize,
    /// Input canvas row words (margin/slack inclusive).
    pub row_words_in: usize,
    /// Window-row read length (padded to vector words).
    pub row_read: usize,
    /// Trace segments per window row.
    pub n_segs: usize,
    pub kernel_words: usize,
    pub k_groups: usize,
    pub c_pad_out: usize,
    pub has_bypass: bool,
    /// Bypass canvas row words (0 without bypass).
    pub byp_row_words: usize,
    /// Constraint cap on `rows_per_cu` (MBuf bank, BBuf bypass budget,
    /// `h_out / n_cus` floor).
    pub max_rows: usize,
    pub dbuf_w: bool,
}

/// Predicted performance of one (layer, schedule) pair.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CostEstimate {
    /// Predicted end-to-end cycles for the layer.
    pub cycles: u64,
    /// Predicted off-chip traffic (loads + stores) in bytes.
    pub dram_bytes: u64,
    /// Resource-bound components (`cycles ≈ startup + max of these + drain`).
    pub compute_cycles: u64,
    pub issue_cycles: u64,
    pub dma_cycles: u64,
    pub startup_cycles: u64,
    /// DMA streams the layer issues (setup-cost driver).
    pub streams: u64,
}

/// Instruction-count estimate of one emitted window (MACs + trace
/// advances), shared by the issue model and the Mloop block-size check.
fn window_instrs(g: &ConvGeom) -> usize {
    // 2 trace-base adds, kh·n_segs MACs, 2 advances per non-final
    // segment, one row-fix add per non-final row, bypass VMOV.
    2 + g.kh * g.n_segs + 2 * (g.kh * g.n_segs - 1) + (g.kh - 1) + g.has_bypass as usize
}

/// Static instruction estimate of the Mloop single-block skeleton
/// (kernel-group loop with the tile loop unrolled inside).
fn mloop_block_instrs(g: &ConvGeom, n_tiles: usize) -> usize {
    50 + n_tiles * (45 + window_instrs(g))
}

/// Whether the maps-resident Mloop skeleton can serve this layer at the
/// given tile height: no fused bypass (the bypass strip is reloaded per
/// tile, which only the Kloop skeleton stages), every strip resident in
/// its own MBuf bank, and the unrolled block inside one icache bank.
pub fn mloop_viable(g: &ConvGeom, cfg: &SnowflakeConfig, rows_per_cu: usize) -> bool {
    if g.has_bypass {
        return false;
    }
    let n_tiles = tile_rows(g.h_out, rows_per_cu, cfg.n_cus).len();
    n_tiles >= 2
        && n_tiles <= cfg.mbuf_banks
        && mloop_block_instrs(g, n_tiles) <= mloop_block_budget(cfg)
}

/// The loop order codegen will actually emit for a requested order.
pub fn effective_order(
    g: &ConvGeom,
    cfg: &SnowflakeConfig,
    order: LoopOrder,
    rows_per_cu: usize,
) -> LoopOrder {
    match order {
        LoopOrder::Mloop if mloop_viable(g, cfg, rows_per_cu) => LoopOrder::Mloop,
        _ => LoopOrder::Kloop,
    }
}

/// Predict cycles and traffic for one schedule. The schedule's order is
/// clamped to what codegen will emit ([`effective_order`]).
pub fn estimate(
    g: &ConvGeom,
    s: &Schedule,
    cfg: &SnowflakeConfig,
    smart_delay_slots: bool,
) -> CostEstimate {
    let order = effective_order(g, cfg, s.order, s.rows_per_cu);
    let split = s.split();
    let n_cus = cfg.n_cus as u64;
    let units = cfg.n_load_units as u64;
    let setup = cfg.dma_setup_cycles;
    let wb = cfg.word_bytes as u64;
    // Millibyte budget per cycle (exact for 16.8 B/cycle).
    let budget_mb = (cfg.axi_bytes_per_cycle * 1000.0).round().max(1.0) as u64;
    let bytes_to_cycles = |bytes: u64| (bytes * 1000).div_ceil(budget_mb);

    let rows_list = tile_rows(g.h_out, s.rows_per_cu, cfg.n_cus);
    let n_tiles = rows_list.len() as u64;
    let strip_words =
        |r: usize| ((r - 1) * g.stride + g.kh + CONV_SPILL_ROWS) * g.row_words_in;
    let pieces = |r: usize| split.min(strip_words(r).div_ceil(64)).max(1);

    // ---- traffic -----------------------------------------------------
    let maps_once: u64 = rows_list.iter().map(|&r| n_cus * strip_words(r) as u64).sum();
    let maps_streams: u64 = rows_list.iter().map(|&r| n_cus * pieces(r) as u64).sum();
    let group_words = 4 * g.kernel_words as u64;
    // Each pass over the kernel stream loads k_groups real groups plus
    // the dummy prefetch group.
    let (kernel_words_all, kernel_streams) = match order {
        LoopOrder::Kloop => (
            n_tiles * (g.k_groups as u64 + 1) * group_words,
            n_tiles * (g.k_groups as u64 + 1) * 4,
        ),
        LoopOrder::Mloop => ((g.k_groups as u64 + 1) * group_words, (g.k_groups as u64 + 1) * 4),
    };
    let byp_words: u64 = if g.has_bypass {
        rows_list.iter().map(|&r| n_cus * (r * g.byp_row_words) as u64).sum()
    } else {
        0
    };
    let byp_streams = if g.has_bypass { n_tiles * n_cus } else { 0 };
    let bias_words = (g.k_groups * 4) as u64;
    let windows_rows: u64 = rows_list.iter().map(|&r| r as u64).sum();
    let stores_words = g.k_groups as u64 * 4 * windows_rows * n_cus * g.w_out as u64;
    let loads_words = maps_once + kernel_words_all + byp_words + bias_words;
    let dram_bytes = (loads_words + stores_words) * wb;
    let streams = maps_streams + kernel_streams + byp_streams + 1;

    // ---- compute (per-CU serial vector work) -------------------------
    let trace = (g.kh * g.row_read / 16) as u64;
    let win_cu = trace + cfg.gather_cycles + g.has_bypass as u64;
    let compute_cycles =
        g.k_groups as u64 * (windows_rows * g.w_out as u64 * win_cu + n_tiles);

    // ---- issue (1 instruction per cycle) -----------------------------
    let byp = g.has_bypass as u64;
    let win_issue = window_instrs(g) as u64;
    let xloop_over: u64 = if smart_delay_slots { 6 } else { 8 + byp };
    let per_y = (win_issue + xloop_over) * g.w_out as u64 + 13 + byp;
    let issue_cycles = g.k_groups as u64 * (windows_rows * per_y + n_tiles * 35)
        + streams * 5
        + 64;

    // ---- DMA ---------------------------------------------------------
    let bus_cycles = bytes_to_cycles(dram_bytes);
    let loads_bytes = loads_words * wb;
    let (worst_unit_streams, worst_unit_bytes) = match s.policy {
        BalancePolicy::OneUnit => (streams, loads_bytes),
        BalancePolicy::TwoUnits => {
            // Maps on unit 0; weights + bias (+ bypass strips, which the
            // codegen issues as Bias-class streams) on unit 1.
            let u0 = (maps_streams, maps_once * wb);
            let u1 = (
                kernel_streams + byp_streams + 1,
                (kernel_words_all + byp_words + bias_words) * wb,
            );
            if u0.0 * setup + bytes_to_cycles(u0.1) >= u1.0 * setup + bytes_to_cycles(u1.1) {
                u0
            } else {
                u1
            }
        }
        BalancePolicy::Greedy { .. } => (streams.div_ceil(units), loads_bytes.div_ceil(units)),
    };
    let per_unit_cycles = worst_unit_streams * setup + bytes_to_cycles(worst_unit_bytes);
    let dma_cycles = bus_cycles.max(per_unit_cycles);

    // ---- startup: serial prefix before the first window --------------
    let (start_words, start_streams) = match order {
        LoopOrder::Kloop => (
            n_cus * strip_words(rows_list[0]) as u64 + group_words,
            n_cus * pieces(rows_list[0]) as u64 + 4,
        ),
        // Mloop stages every resident strip before compute.
        LoopOrder::Mloop => (maps_once + group_words, maps_streams + 4),
    };
    let startup_cycles =
        30 + start_streams.div_ceil(units) * setup + bytes_to_cycles(start_words * wb);

    let cycles = startup_cycles + compute_cycles.max(issue_cycles).max(dma_cycles) + 150;
    CostEstimate {
        cycles,
        dram_bytes,
        compute_cycles,
        issue_cycles,
        dma_cycles,
        startup_cycles,
        streams,
    }
}

/// The seed heuristic schedule: capacity-maximal tile height, the
/// global balance policy, and the Kloop skeleton — the only one the
/// seed codegen ever emitted (its §6.2 two-way traffic compare was an
/// annotation codegen never consumed; that analysis is preserved in
/// `decide::required_bandwidth_gbs` / Figure 4). `TuneMode::Heuristic`
/// therefore reproduces seed *emission* bit-for-bit.
pub fn seed_heuristic(g: &ConvGeom, _cfg: &SnowflakeConfig, opts: &CompileOptions) -> Schedule {
    Schedule {
        order: LoopOrder::Kloop,
        rows_per_cu: g.max_rows.max(1),
        policy: opts.balance,
    }
}

/// Bounded tile-height candidate set (see the module docs).
fn rows_candidates(g: &ConvGeom, n_cus: usize) -> Vec<usize> {
    let cap = g.max_rows.max(1);
    let mut set = std::collections::BTreeSet::new();
    for r in 1..=cap.min(8) {
        set.insert(r);
    }
    set.insert(cap);
    if cap > 1 {
        set.insert(cap - 1);
    }
    for t in 1..=4usize {
        let r = g.h_out.div_ceil(n_cus * t);
        if (1..=cap).contains(&r) {
            set.insert(r);
        }
    }
    set.into_iter().collect()
}

/// Balance-policy candidates: the Greedy split spectrum, or the pinned
/// non-Greedy base policy.
fn policy_candidates(base: BalancePolicy) -> Vec<BalancePolicy> {
    match base {
        BalancePolicy::Greedy { split } => {
            let mut splits = vec![1usize, 2, 4, 8];
            if !splits.contains(&split.max(1)) {
                splits.push(split.max(1));
                splits.sort_unstable();
            }
            splits.into_iter().map(|s| BalancePolicy::Greedy { split: s }).collect()
        }
        other => vec![other],
    }
}

/// Every candidate schedule for the layer (valid by construction).
pub fn candidates(g: &ConvGeom, cfg: &SnowflakeConfig, base: BalancePolicy) -> Vec<Schedule> {
    let mut out = Vec::new();
    for rows in rows_candidates(g, cfg.n_cus) {
        for policy in policy_candidates(base) {
            out.push(Schedule { order: LoopOrder::Kloop, rows_per_cu: rows, policy });
            if mloop_viable(g, cfg, rows) {
                out.push(Schedule { order: LoopOrder::Mloop, rows_per_cu: rows, policy });
            }
        }
    }
    out
}

/// All candidates ranked by predicted cycles (then bytes) — the measured
/// tuner's top-K source. The seed heuristic's schedule is always
/// included.
pub fn ranked(
    g: &ConvGeom,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> Vec<(Schedule, CostEstimate)> {
    let mut all: Vec<(Schedule, CostEstimate)> = Vec::new();
    let push = |s: Schedule, all: &mut Vec<(Schedule, CostEstimate)>| {
        if !all.iter().any(|(q, _)| *q == s) {
            // Rank delay-slot-agnostically (see `search`).
            all.push((s, estimate(g, &s, cfg, false)));
        }
    };
    push(seed_heuristic(g, cfg, opts), &mut all);
    for s in candidates(g, cfg, opts.balance) {
        push(s, &mut all);
    }
    all.sort_by_key(|(_, e)| (e.cycles, e.dram_bytes));
    all
}

/// Argmin of the analytical model over the candidate space, with
/// hysteresis: the winner must beat the seed heuristic's predicted
/// cycles by [`DISPLACE_MARGIN_PCT`] percent, otherwise the seed's
/// schedule is kept (sub-margin deltas are model noise). A
/// `force_loop_order` in `opts` restricts the space to schedules that
/// genuinely emit that order (falling back to Kloop candidates when no
/// viable Mloop schedule exists for the layer) and disables the seed
/// hysteresis when the seed's order is excluded.
pub fn search(
    g: &ConvGeom,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> (Schedule, CostEstimate) {
    // Rank with plain delay slots regardless of `smart_delay_slots`:
    // hand and auto compiles then pick identical schedules, so smart
    // mode only ever shortens the same program (the Table 1 invariant).
    let smart = false;
    let mut cands = candidates(g, cfg, opts.balance);
    match opts.force_loop_order {
        Some(LoopOrder::Kloop) => cands.retain(|s| s.order == LoopOrder::Kloop),
        Some(LoopOrder::Mloop) if cands.iter().any(|s| s.order == LoopOrder::Mloop) => {
            cands.retain(|s| s.order == LoopOrder::Mloop)
        }
        _ => {}
    }
    let seed = seed_heuristic(g, cfg, opts);
    let seed_eligible = match opts.force_loop_order {
        None => true,
        Some(o) => o == seed.order,
    };
    let mut best_s = if seed_eligible {
        seed
    } else {
        // Forced away from the seed's order: start from the first
        // filtered candidate instead.
        cands.first().copied().unwrap_or(seed)
    };
    let mut best_e = estimate(g, &best_s, cfg, smart);
    let seed_e = if best_s == seed { best_e } else { estimate(g, &seed, cfg, smart) };
    for s in cands {
        if s == best_s {
            continue;
        }
        let e = estimate(g, &s, cfg, smart);
        if e.cycles < best_e.cycles
            || (e.cycles == best_e.cycles && e.dram_bytes < best_e.dram_bytes)
        {
            best_s = s;
            best_e = e;
        }
    }
    if seed_eligible
        && best_s != seed
        && best_e.cycles.saturating_mul(100) >= seed_e.cycles.saturating_mul(100 - DISPLACE_MARGIN_PCT)
    {
        return (seed, seed_e);
    }
    (best_s, best_e)
}

// ---------------------------------------------------------------------
// Pool schedules (ROADMAP follow-on from ISSUE 2)
// ---------------------------------------------------------------------

/// Maxpool geometry the pool cost model needs — everything `decide`
/// derives before choosing the strip height. Pool strips share the
/// conv maps' startup-vs-volume trade: taller strips mean fewer tiles
/// (fewer DMA streams, less per-tile loop overhead) but a longer
/// serial prefix before the first MAX can issue.
#[derive(Clone, Copy, Debug)]
pub struct PoolGeom {
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub h_out: usize,
    pub w_out: usize,
    /// Real (unpadded) channels — the per-row write volume and the
    /// channel-loop trip count.
    pub c: usize,
    pub c_pad: usize,
    /// Input canvas row words (margin/slack inclusive).
    pub row_words_in: usize,
    /// Strip spill rows (lane overreach past the last window).
    pub spill: usize,
    /// Constraint cap on `rows_per_cu` (MBuf bank, `h_out/n_cus`).
    pub max_rows: usize,
}

/// Predict cycles/traffic for a maxpool layer at one strip height.
/// Mirrors `codegen/pool.rs::emit_maxpool`: per tile, each CU streams
/// one strip and then issues `rows × c × x_groups × kh·kw` MAX ops
/// (1 cycle each on the pool unit), with the channel/row loop overhead
/// on the issue stage. Same shape as the conv estimate:
/// `startup + max(compute, issue, dma) + drain`.
pub fn pool_estimate(
    g: &PoolGeom,
    rows_per_cu: usize,
    split: usize,
    cfg: &SnowflakeConfig,
) -> CostEstimate {
    let n_cus = cfg.n_cus as u64;
    let units = cfg.n_load_units as u64;
    let setup = cfg.dma_setup_cycles;
    let wb = cfg.word_bytes as u64;
    let budget_mb = (cfg.axi_bytes_per_cycle * 1000.0).round().max(1.0) as u64;
    let bytes_to_cycles = |bytes: u64| (bytes * 1000).div_ceil(budget_mb);

    let rows_list = tile_rows(g.h_out, rows_per_cu, cfg.n_cus);
    let n_tiles = rows_list.len() as u64;
    let strip_words = |r: usize| ((r - 1) * g.stride + g.kh + g.spill) * g.row_words_in;
    let pieces = |r: usize| split.min(strip_words(r).div_ceil(64)).max(1);

    // ---- traffic -----------------------------------------------------
    let maps_once: u64 = rows_list.iter().map(|&r| n_cus * strip_words(r) as u64).sum();
    let streams: u64 = rows_list.iter().map(|&r| n_cus * pieces(r) as u64).sum();
    let windows_rows: u64 = rows_list.iter().map(|&r| r as u64).sum();
    let stores_words = windows_rows * n_cus * (g.c * g.w_out) as u64;
    let dram_bytes = (maps_once + stores_words) * wb;

    // ---- compute (pool unit, 1 cycle per MAX) ------------------------
    let x_groups = g.w_out.div_ceil(16) as u64;
    let taps = (g.kh * g.kw) as u64;
    let compute_cycles = windows_rows * g.c as u64 * x_groups * taps;

    // ---- issue -------------------------------------------------------
    // Per x-group: 2 address adds + taps MAXes + taps-1 advances; per
    // channel iteration ~8 loop-control instructions (branch + delay
    // slots + the two +1 walks); per row ~13; per tile ~10 + the
    // next-tile strip loads (5 instrs per stream).
    let per_group = 1 + 2 * taps;
    let per_chan = x_groups * per_group + 8;
    let per_row = g.c as u64 * per_chan + 13;
    let issue_cycles = windows_rows * per_row + n_tiles * 10 + streams * 5;

    // ---- DMA ---------------------------------------------------------
    let bus_cycles = bytes_to_cycles(maps_once * wb);
    let per_unit_cycles = streams.div_ceil(units) * setup + bytes_to_cycles(maps_once * wb / units.max(1));
    let dma_cycles = bus_cycles.max(per_unit_cycles);

    // ---- startup: tile-0 strips before the first MAX -----------------
    let start_streams = n_cus * pieces(rows_list[0]) as u64;
    let start_bytes = n_cus * strip_words(rows_list[0]) as u64 * wb;
    let startup_cycles = 20 + start_streams.div_ceil(units) * setup + bytes_to_cycles(start_bytes);

    let cycles = startup_cycles + compute_cycles.max(issue_cycles).max(dma_cycles) + 150;
    CostEstimate {
        cycles,
        dram_bytes,
        compute_cycles,
        issue_cycles,
        dma_cycles,
        startup_cycles,
        streams,
    }
}

/// The maps-split factor pool strip loads inherit from the base
/// balance policy (pool layers have no per-layer policy knob).
pub fn pool_split(opts: &CompileOptions) -> usize {
    match opts.balance {
        BalancePolicy::Greedy { split } => split.max(1),
        _ => 1,
    }
}

/// Argmin of [`pool_estimate`] over the strip-height candidates, with
/// the same hysteresis as the conv search: the seed heuristic
/// (capacity-maximal `max_rows`) is kept unless a candidate beats its
/// prediction by [`DISPLACE_MARGIN_PCT`] percent. Candidate set mirrors
/// [`rows_candidates`]: small heights, the cap and cap−1, and heights
/// giving exactly 1..=4 tiles.
pub fn pool_search(
    g: &PoolGeom,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> (usize, CostEstimate) {
    let split = pool_split(opts);
    let seed = g.max_rows.max(1);
    let seed_e = pool_estimate(g, seed, split, cfg);
    let mut cands = std::collections::BTreeSet::new();
    for r in 1..=seed.min(8) {
        cands.insert(r);
    }
    cands.insert(seed);
    if seed > 1 {
        cands.insert(seed - 1);
    }
    for t in 1..=4usize {
        let r = g.h_out.div_ceil(cfg.n_cus * t);
        if (1..=seed).contains(&r) {
            cands.insert(r);
        }
    }
    let (mut best_r, mut best_e) = (seed, seed_e);
    for r in cands {
        if r == best_r {
            continue;
        }
        let e = pool_estimate(g, r, split, cfg);
        if e.cycles < best_e.cycles
            || (e.cycles == best_e.cycles && e.dram_bytes < best_e.dram_bytes)
        {
            best_r = r;
            best_e = e;
        }
    }
    if best_r != seed
        && best_e.cycles.saturating_mul(100)
            >= seed_e.cycles.saturating_mul(100 - DISPLACE_MARGIN_PCT)
    {
        return (seed, seed_e);
    }
    (best_r, best_e)
}

/// Check an explicit override against the layer's constraint caps. An
/// explicitly requested Mloop that the skeleton cannot emit is an
/// error, not a silent Kloop fallback — only `force_loop_order` (a
/// whole-model knob) degrades gracefully.
pub fn validate(s: &Schedule, g: &ConvGeom, cfg: &SnowflakeConfig) -> Result<(), String> {
    if s.rows_per_cu < 1 || s.rows_per_cu > g.max_rows {
        return Err(format!(
            "schedule rows_per_cu {} outside 1..={} for this layer",
            s.rows_per_cu, g.max_rows
        ));
    }
    if s.order == LoopOrder::Mloop && !mloop_viable(g, cfg, s.rows_per_cu) {
        return Err(format!(
            "explicit Mloop schedule is not emittable for this layer at rows_per_cu {} \
             (needs 2..={} resident map tiles, no fused bypass, and the unrolled block \
             within an icache bank)",
            s.rows_per_cu, cfg.mbuf_banks
        ));
    }
    if s.split() > 64 {
        return Err(format!("schedule split {} unreasonably large (max 64)", s.split()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// AlexNet-conv2-class geometry (27x27, 5x5, 64 -> 192).
    fn conv2_geom() -> ConvGeom {
        ConvGeom {
            kh: 5,
            stride: 1,
            h_out: 27,
            w_out: 27,
            row_words_in: (27 + 2 * 2) * 64,
            row_read: 320,
            n_segs: 1,
            kernel_words: 5 * 320,
            k_groups: 48,
            c_pad_out: 192,
            has_bypass: false,
            byp_row_words: 0,
            max_rows: 6,
            dbuf_w: true,
        }
    }

    #[test]
    fn candidate_space_is_bounded_and_contains_heuristic() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let opts = CompileOptions::default();
        let cands = candidates(&g, &cfg, opts.balance);
        assert!(!cands.is_empty());
        assert!(cands.len() <= 128, "unbounded candidate space: {}", cands.len());
        let h = seed_heuristic(&g, &cfg, &opts);
        assert!(
            cands.iter().any(|s| *s == h),
            "heuristic schedule {h:?} missing from the candidate space"
        );
        for s in &cands {
            assert!(validate(s, &g, &cfg).is_ok(), "{s:?}");
            assert!((1..=g.max_rows).contains(&s.rows_per_cu));
        }
        // The seed reproduces the seed codegen: Kloop, capacity rows.
        assert_eq!(h.order, LoopOrder::Kloop);
        assert_eq!(h.rows_per_cu, g.max_rows);
    }

    #[test]
    fn mloop_cuts_kernel_traffic_on_two_tile_layers() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom(); // max_rows 6 -> tiles [6, 1]
        assert!(mloop_viable(&g, &cfg, 6));
        let pol = BalancePolicy::Greedy { split: 2 };
        let k = estimate(
            &g,
            &Schedule { order: LoopOrder::Kloop, rows_per_cu: 6, policy: pol },
            &cfg,
            false,
        );
        let m = estimate(
            &g,
            &Schedule { order: LoopOrder::Mloop, rows_per_cu: 6, policy: pol },
            &cfg,
            false,
        );
        assert!(m.dram_bytes < k.dram_bytes, "mloop {} !< kloop {}", m.dram_bytes, k.dram_bytes);
        // Same compute either way (identical window work).
        assert_eq!(m.compute_cycles, k.compute_cycles);
    }

    #[test]
    fn mloop_unavailable_with_bypass_or_single_tile() {
        let cfg = SnowflakeConfig::default();
        let mut g = conv2_geom();
        g.has_bypass = true;
        g.byp_row_words = 31 * 192;
        assert!(!mloop_viable(&g, &cfg, 6));
        let mut g1 = conv2_geom();
        g1.h_out = 24; // 6 rows x 4 CUs: one tile
        assert!(!mloop_viable(&g1, &cfg, 6));
        assert_eq!(
            effective_order(&g1, &cfg, LoopOrder::Mloop, 6),
            LoopOrder::Kloop,
            "single-tile Mloop must clamp to the (identical) Kloop skeleton"
        );
    }

    #[test]
    fn search_is_deterministic_and_never_worse_than_heuristic() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let opts = CompileOptions::default();
        let (s1, e1) = search(&g, &cfg, &opts);
        let (s2, e2) = search(&g, &cfg, &opts);
        assert_eq!(s1, s2);
        assert_eq!(e1, e2);
        let h = seed_heuristic(&g, &cfg, &opts);
        let he = estimate(&g, &h, &cfg, false);
        assert!(e1.cycles <= he.cycles, "search {e1:?} worse than heuristic {he:?}");
    }

    #[test]
    fn split_trades_setup_for_balance() {
        // More pieces -> more streams -> more predicted setup cost.
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let e1 = estimate(
            &g,
            &Schedule {
                order: LoopOrder::Kloop,
                rows_per_cu: 6,
                policy: BalancePolicy::Greedy { split: 1 },
            },
            &cfg,
            false,
        );
        let e8 = estimate(
            &g,
            &Schedule {
                order: LoopOrder::Kloop,
                rows_per_cu: 6,
                policy: BalancePolicy::Greedy { split: 8 },
            },
            &cfg,
            false,
        );
        assert!(e8.streams > e1.streams);
        assert_eq!(e8.dram_bytes, e1.dram_bytes, "split must not change traffic volume");
    }

    #[test]
    fn one_unit_predicts_slower_dma_than_greedy() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let gr = estimate(
            &g,
            &Schedule {
                order: LoopOrder::Kloop,
                rows_per_cu: 6,
                policy: BalancePolicy::Greedy { split: 2 },
            },
            &cfg,
            false,
        );
        let one = estimate(
            &g,
            &Schedule { order: LoopOrder::Kloop, rows_per_cu: 6, policy: BalancePolicy::OneUnit },
            &cfg,
            false,
        );
        assert!(one.dma_cycles >= gr.dma_cycles);
    }

    #[test]
    fn validate_rejects_out_of_cap_rows_and_unemittable_mloop() {
        let cfg = SnowflakeConfig::default();
        let g = conv2_geom();
        let bad = Schedule {
            order: LoopOrder::Kloop,
            rows_per_cu: g.max_rows + 1,
            policy: BalancePolicy::default(),
        };
        assert!(validate(&bad, &g, &cfg).is_err());
        let ok = Schedule { rows_per_cu: 1, ..bad };
        assert!(validate(&ok, &g, &cfg).is_ok());
        // rows 1 -> 7 tiles: an explicit Mloop request must error, not
        // silently fall back to Kloop.
        let mloop_bad = Schedule { order: LoopOrder::Mloop, ..ok };
        assert!(validate(&mloop_bad, &g, &cfg).is_err());
        let mloop_ok = Schedule { order: LoopOrder::Mloop, rows_per_cu: 6, ..ok };
        assert!(validate(&mloop_ok, &g, &cfg).is_ok());
    }

    /// AlexNet-pool1-class geometry (55x55 -> 27x27, 3x3 stride 2).
    fn pool1_geom() -> PoolGeom {
        PoolGeom {
            kh: 3,
            kw: 3,
            stride: 2,
            h_out: 27,
            w_out: 27,
            c: 64,
            c_pad: 64,
            row_words_in: 55 * 64,
            spill: 1,
            max_rows: 4,
        }
    }

    #[test]
    fn pool_estimate_tracks_the_real_tradeoffs() {
        let cfg = SnowflakeConfig::default();
        let g = pool1_geom();
        let tall = pool_estimate(&g, g.max_rows, 2, &cfg);
        let short = pool_estimate(&g, 1, 2, &cfg);
        assert!(tall.cycles > 0 && short.cycles > 0);
        // Shorter strips mean more tiles, hence more DMA streams and a
        // smaller serial startup prefix.
        assert!(short.streams > tall.streams);
        assert!(short.startup_cycles < tall.startup_cycles);
        // Compute volume is height-independent (same windows either way).
        assert_eq!(short.compute_cycles, tall.compute_cycles);
        // Shorter strips re-stream more window-overlap rows.
        assert!(short.dram_bytes >= tall.dram_bytes);
    }

    #[test]
    fn pool_search_keeps_seed_on_ties_and_stays_in_cap() {
        let cfg = SnowflakeConfig::default();
        let g = pool1_geom();
        let opts = CompileOptions::default();
        let (rows, e) = pool_search(&g, &cfg, &opts);
        assert!((1..=g.max_rows).contains(&rows));
        let seed_e = pool_estimate(&g, g.max_rows, pool_split(&opts), &cfg);
        assert!(e.cycles <= seed_e.cycles, "search result predicted worse than the seed");
        // A candidate inside the hysteresis margin must not displace the
        // seed: search on a single-candidate space returns the seed.
        let tiny = PoolGeom { max_rows: 1, h_out: 4, ..g };
        let (r1, _) = pool_search(&tiny, &cfg, &opts);
        assert_eq!(r1, 1);
    }
}
