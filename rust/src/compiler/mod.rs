//! The Snowflake compiler — the paper's contribution (§5).
//!
//! Three tasks, mirroring §5's structure:
//!
//! 1. **Model parsing** (§5.1): [`crate::model`] supplies steps 1–2
//!    (layer objects + dependency labels); [`decide`] is step 3 (mode,
//!    loop order, tile limits from the shared hardware parameter
//!    object); [`tile`] is step 4 (row-strip map tiles, single-kernel
//!    weight tiles, channel/row chunking); the per-tile operation lists
//!    of step 5 live inside [`codegen`]'s emitters.
//! 2. **Instruction generation** (§5.2): [`codegen`] emits per-tile
//!    instruction blocks, predicts block sizes against the icache bank
//!    constraint, packs blocks into banks with the double-buffered
//!    icache load prologues, fills branch delay slots and runs the
//!    [`crate::isa::verify`] pass. [`balance`] assigns LD instructions
//!    to the four load units (§6.3).
//! 3. **Instruction deployment** (§5.3): [`layout`] places canvases,
//!    weights, biases and the encoded stream in (simulated) CMA memory;
//!    [`deploy`] arranges and writes the data per the COOP/INDP decision
//!    and reads results back.
//!
//! [`hand`] holds the hand-optimized baseline streams for Table 1.

pub mod artifact;
pub mod balance;
pub mod codegen;
pub mod cost;
pub mod decide;
pub mod deploy;
pub mod hand;
pub mod layout;
pub mod measure_cache;
pub mod partition;
pub mod tile;

pub use artifact::{Artifact, ArtifactError, ArtifactFormat, ArtifactMeta};

use crate::arch::SnowflakeConfig;
use crate::fixed::QFormat;
use crate::isa::instr::Program;
use crate::model::graph::Graph;
use std::collections::BTreeMap;

/// Loop-rearrangement choice (§6.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopOrder {
    /// Maps data re-sent per kernel tile (kernels resident).
    Mloop,
    /// Kernel data re-sent per map tile (maps resident).
    Kloop,
    /// Banked-rotation Mloop: kernels still read exactly once (one
    /// WBuf-region-resident kernel *set* per pass), map strips rotated
    /// through the MBuf banks with double-buffered prefetch — the
    /// kernel-traffic elimination of [`LoopOrder::Mloop`] extended to
    /// layers with more map tiles than MBuf banks, at the price of one
    /// map-strip pass per kernel set ([`cost::rot_sets`]).
    MloopRot,
}

/// MAC operating mode (§4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MacMode {
    Coop,
    Indp,
}

/// Load-balancing policy for LD unit assignment (§6.3 / Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BalancePolicy {
    /// Greedy least-loaded-unit assignment with map loads split into
    /// `split` pieces (1 = no splitting). Higher split = finer balance.
    Greedy { split: usize },
    /// The paper's worst case: kernels and maps each pinned to two units.
    TwoUnits,
    /// Everything on one unit (degenerate baseline).
    OneUnit,
}

impl Default for BalancePolicy {
    fn default() -> Self {
        BalancePolicy::Greedy { split: 2 }
    }
}

/// How the compiler picks each conv layer's schedule (loop order ×
/// tile height × maps-split × balance policy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneMode {
    /// The seed heuristic: maximize `rows_per_cu` to buffer capacity,
    /// emit the Kloop skeleton (the only one the seed codegen produced;
    /// its §6.2 traffic-compare annotation was never consumed — that
    /// analysis lives on in `decide::required_bandwidth_gbs`/Figure 4),
    /// use the global `balance` policy unchanged. Reproduces seed
    /// emission bit-for-bit.
    Heuristic,
    /// Enumerate the bounded candidate space and pick the schedule with
    /// the fewest cycles predicted by the analytical model
    /// ([`cost::search`]). The default.
    Analytical,
    /// Analytical at compile time; the *measured* refinement — compile
    /// the top-K predicted candidates per layer and simulate each — is
    /// driven by [`crate::coordinator::tune`], which passes the winning
    /// per-layer schedules back through [`CompileOptions::schedules`].
    Measured {
        /// Candidates simulated per layer (including the incumbent).
        top_k: usize,
    },
}

impl Default for TuneMode {
    fn default() -> Self {
        TuneMode::Analytical
    }
}

/// Explicit per-layer conv schedules, keyed by lowered-op node id
/// (`Lowered::out_node`). Entries override the tuner.
pub type ScheduleMap = BTreeMap<usize, cost::Schedule>;

/// Compiler options.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    pub fmt: QFormat,
    /// Balance policy for non-conv layers, and the base policy family
    /// the conv tuner searches within (a non-Greedy policy pins every
    /// layer to it; Greedy lets the tuner pick a per-layer split).
    pub balance: BalancePolicy,
    /// Force a loop order for every conv (None = per-layer decision).
    /// Wins over the tuner and over `schedules`. `Some(Mloop)` means
    /// the Mloop *family*: the maps-resident skeleton where it fits,
    /// the banked-rotation skeleton where only rotation can keep the
    /// kernel stream single-pass; convs neither skeleton can serve
    /// (fused bypass, oversized unrolled blocks) still fall back to
    /// Kloop.
    pub force_loop_order: Option<LoopOrder>,
    /// Conv schedule selection mode (see [`TuneMode`]).
    pub tune: TuneMode,
    /// Per-layer schedule overrides (measured tuning, debugging).
    pub schedules: ScheduleMap,
    /// Fill branch delay slots with useful tail instructions (the
    /// hand-optimization of Table 1); false pads with no-ops.
    pub smart_delay_slots: bool,
    /// Reuse output regions of `Sequential` nodes (step-2 labels).
    pub reuse_regions: bool,
    /// Skip FC layers in generated code (the paper excludes FC from
    /// reported execution time; compilation of FC is still supported).
    pub skip_fc: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            fmt: crate::fixed::Q8_8,
            balance: BalancePolicy::default(),
            force_loop_order: None,
            tune: TuneMode::default(),
            schedules: ScheduleMap::new(),
            smart_delay_slots: false,
            reuse_regions: false,
            skip_fc: false,
        }
    }
}

/// Compilation failure.
#[derive(Debug, Clone)]
pub struct CompileError(pub String);

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "compile error: {}", self.0)
    }
}

impl std::error::Error for CompileError {}

/// A compiled model: the instruction stream plus the memory plan needed
/// to deploy weights/input and read results back.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledModel {
    pub program: Program,
    pub plan: layout::Plan,
    /// Per-layer instruction ranges (reporting/debug).
    pub layer_ranges: Vec<(usize, String, std::ops::Range<usize>)>,
    /// Generated instructions before bank padding (the count Table 1
    /// compares; `program.len()` includes alignment/spare-bank HALTs).
    pub code_len: usize,
}

/// The builder-style front door: configure once, build versioned
/// [`Artifact`]s for any number of graphs.
///
/// ```ignore
/// let artifact = Compiler::new(cfg).options(opts).build(&graph)?;
/// artifact.save("alexnet.artifact.json")?;
/// ```
///
/// `build` is `compile` plus the deployment packaging: the artifact
/// carries the program, the full memory plan, the chosen per-layer
/// schedules, the embedded model description and the hardware-config
/// fingerprint, so a runtime ([`crate::engine::Engine`]) can execute it
/// without ever re-running the compiler.
#[derive(Clone, Debug)]
pub struct Compiler {
    cfg: SnowflakeConfig,
    opts: CompileOptions,
}

impl Compiler {
    /// A compiler for the given hardware configuration with default
    /// options.
    pub fn new(cfg: SnowflakeConfig) -> Self {
        Compiler { cfg, opts: CompileOptions::default() }
    }

    /// Replace the full option set (builder style).
    pub fn options(mut self, opts: CompileOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Set the schedule-selection mode only.
    pub fn tune(mut self, tune: TuneMode) -> Self {
        self.opts.tune = tune;
        self
    }

    /// Set explicit per-layer schedule overrides only.
    pub fn schedules(mut self, schedules: ScheduleMap) -> Self {
        self.opts.schedules = schedules;
        self
    }

    /// The configuration this compiler targets.
    pub fn config(&self) -> &SnowflakeConfig {
        &self.cfg
    }

    /// Compile to just the compiled model — the old `compile()` surface
    /// for callers that never serialize or serve the artifact (tests,
    /// benches, compile-only tools). Skips the artifact packaging
    /// (graph clone, schedule map, metadata) `build` would discard.
    pub fn compile(&self, graph: &Graph) -> Result<CompiledModel, CompileError> {
        compile_impl(graph, &self.cfg, &self.opts)
    }

    /// Compile `graph` into a versioned, serializable [`Artifact`].
    pub fn build(&self, graph: &Graph) -> Result<Artifact, CompileError> {
        let compiled = compile_impl(graph, &self.cfg, &self.opts)?;
        let schedules = compiled.plan.conv_schedules();
        let output_node = compiled
            .plan
            .layers
            .iter()
            .rev()
            .find(|lp| !(self.opts.skip_fc && matches!(lp.op, layout::Lowered::Fc { .. })))
            .map(|lp| lp.op.out_node());
        Ok(Artifact {
            cfg: self.cfg.clone(),
            graph: graph.clone(),
            compiled,
            schedules,
            output_node,
            meta: ArtifactMeta::of(&self.opts),
        })
    }
}

/// The compile pipeline behind [`Compiler::compile`] and
/// [`Compiler::build`]. (The free-function `compile()` shim this once
/// backed was removed in ISSUE 8 — `Compiler` is the only front door.)
pub(crate) fn compile_impl(
    graph: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> Result<CompiledModel, CompileError> {
    graph.validate().map_err(CompileError)?;
    let plan = layout::plan(graph, cfg, opts)?;
    codegen::generate(graph, cfg, opts, plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_default() {
        let o = CompileOptions::default();
        assert_eq!(o.balance, BalancePolicy::Greedy { split: 2 });
        assert!(o.force_loop_order.is_none());
        assert_eq!(o.tune, TuneMode::Analytical);
        assert!(o.schedules.is_empty());
    }

    #[test]
    fn builder_compile_and_build_agree() {
        use crate::model::layer::{LayerKind, Shape};
        let mut g = crate::model::graph::Graph::new("front_door", Shape::new(16, 8, 8));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c",
        );
        let cfg = SnowflakeConfig::default();
        let compiler = Compiler::new(cfg);
        let artifact = compiler.build(&g).unwrap();
        let compiled = compiler.compile(&g).unwrap();
        assert_eq!(artifact.compiled, compiled, "compile() must stay build() minus packaging");
        // The artifact records the schedules the plan actually used and
        // the output node the Engine will read.
        assert_eq!(artifact.schedules, artifact.compiled.plan.conv_schedules());
        assert_eq!(artifact.output_node, Some(0));
        assert_eq!(artifact.meta.tune, "analytical");
    }
}
