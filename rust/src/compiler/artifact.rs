//! Versioned, serializable compiled artifacts — the §5.3 deployment
//! product as a first-class object.
//!
//! The paper's compiler exists to be *deployed*: instructions and data
//! are arranged once and then executed many times on the accelerator
//! (§5.3 "Instruction deployment"). An [`Artifact`] captures everything
//! a runtime needs to do that without re-running the compiler:
//!
//! * the encoded instruction [`Program`] (with its assembler comments,
//!   so a loaded artifact disassembles identically),
//! * the full memory [`Plan`] — canvases, weight/bias placement, the
//!   program image address — down to every per-layer `OpPlan` decision,
//! * the chosen per-conv-layer [`Schedule`]s (replayable through
//!   [`CompileOptions::schedules`]),
//! * the model description itself (the `model/parser.rs` JSON form), so
//!   the runtime can synthesize/arrange weights and inputs,
//! * provenance: compiler options, the [`FORMAT_VERSION`], and a
//!   **config fingerprint** of the [`SnowflakeConfig`] the artifact was
//!   compiled for.
//!
//! Loading validates the format version, the config fingerprint against
//! the *loading* machine's configuration, and an FNV-1a checksum over
//! the encoded instruction words — a wrong hardware config or a
//! corrupted payload is a typed [`ArtifactError`], never a silent
//! miscompute. The on-disk form is JSON via `util/json.rs` (the repo is
//! dependency-free; see rust/Cargo.toml), self-describing and diffable.
//!
//! The full build → save → load → run cycle:
//!
//! ```ignore
//! let artifact = Compiler::new(cfg.clone()).options(opts).build(&graph)?;
//! artifact.save("model.artifact.json")?;                     // `repro build`
//! let back = Artifact::load("model.artifact.json", &cfg)?;   // validated
//! let mut engine = Engine::new(cfg);                         // `repro run --artifact`
//! let h = engine.load(back, seed)?;
//! let out = engine.infer(h, &input)?;                        // == compile-and-run, exactly
//! ```

use super::cost::{CostEstimate, Schedule};
use super::decide::{AvgPlan, ConvPlan, FcPlan, Geom, OpPlan, PoolPlan};
use super::layout::{Canvas, LayerPlan, Lowered, Plan};
use super::{BalancePolicy, CompileOptions, CompiledModel, LoopOrder, ScheduleMap, TuneMode};
use crate::arch::SnowflakeConfig;
use crate::fixed::QFormat;
use crate::isa::encode::{decode, encode};
use crate::isa::instr::Program;
use crate::model::graph::Graph;
use crate::model::parser;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// On-disk artifact format version. Bump on any incompatible change to
/// the serialized layout; loaders hard-error on mismatch.
///
/// v2 (ISSUE 5): the banked-rotation loop order (`"mloop-rot"`) joined
/// the `Schedule`/`ConvPlan` codecs. v1 readers would reject the new
/// order string as corrupt, and v1 artifacts predate the rotation
/// skeleton's cost model, so both directions hard-error on the version
/// instead of guessing.
///
/// v3 (ISSUE 8): `link_bandwidth_gbs` joined the `SnowflakeConfig`
/// schema — and therefore the config fingerprint. A v2 artifact's hash
/// was computed without the field, so it can never match a v3 host's;
/// rejecting on the version gives the typed "rebuild" message instead
/// of a confusing config-mismatch hex pair.
pub const FORMAT_VERSION: u64 = 3;

/// Magic tag identifying an artifact file.
pub const FORMAT_MAGIC: &str = "snowflake-artifact";

/// Why an artifact could not be saved or loaded.
#[derive(Debug, Clone, PartialEq)]
pub enum ArtifactError {
    /// Filesystem failure (path included in the message).
    Io(String),
    /// The payload is not valid JSON or is missing required fields.
    Parse(String),
    /// Not an artifact file at all (magic tag mismatch).
    NotAnArtifact,
    /// The artifact was written by an incompatible format version.
    FormatVersion { found: u64, expected: u64 },
    /// The artifact was compiled for different hardware: running it on
    /// this configuration would silently miscompute addresses/timing.
    ConfigMismatch { artifact: String, host: String },
    /// The payload decoded but failed an integrity check (checksum,
    /// instruction decode, internal consistency).
    Corrupt(String),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io(m) => write!(f, "artifact io error: {m}"),
            ArtifactError::Parse(m) => write!(f, "artifact parse error: {m}"),
            ArtifactError::NotAnArtifact => {
                write!(f, "not a snowflake artifact (magic tag missing)")
            }
            ArtifactError::FormatVersion { found, expected } => write!(
                f,
                "artifact format version {found} is not supported (expected {expected}); \
                 rebuild the artifact with `repro build`"
            ),
            ArtifactError::ConfigMismatch { artifact, host } => write!(
                f,
                "artifact was compiled for config {artifact} but this machine is {host}; \
                 rebuild the artifact for this hardware configuration"
            ),
            ArtifactError::Corrupt(m) => write!(f, "artifact corrupt: {m}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

/// Compiler provenance recorded in the artifact (informational; the
/// binding facts — program, plan, schedules — are stored explicitly).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// `TuneMode` the artifact was built under (display form).
    pub tune: String,
    /// Base balance policy (display form).
    pub balance: String,
    pub smart_delay_slots: bool,
    pub reuse_regions: bool,
    pub skip_fc: bool,
}

impl ArtifactMeta {
    pub fn of(opts: &CompileOptions) -> Self {
        let tune = match opts.tune {
            TuneMode::Heuristic => "heuristic".to_string(),
            TuneMode::Analytical => "analytical".to_string(),
            TuneMode::Measured { top_k } => format!("measured(top_k={top_k})"),
        };
        ArtifactMeta {
            tune,
            balance: policy_str(opts.balance),
            smart_delay_slots: opts.smart_delay_slots,
            reuse_regions: opts.reuse_regions,
            skip_fc: opts.skip_fc,
        }
    }
}

/// A versioned compiled artifact: everything `build` produced, ready to
/// save/load and to hand to the [`crate::engine::Engine`].
#[derive(Clone, Debug)]
pub struct Artifact {
    /// The hardware configuration the program was compiled for.
    pub cfg: SnowflakeConfig,
    /// The model graph (embedded so the artifact is self-contained).
    pub graph: Graph,
    /// Program + memory plan + layer ranges (the compile output).
    pub compiled: CompiledModel,
    /// Chosen per-conv-layer schedules, keyed by lowered node id —
    /// replayable through [`CompileOptions::schedules`].
    pub schedules: ScheduleMap,
    /// Node whose canvas holds the final generated output (None when
    /// every layer was skipped, e.g. an all-FC model under `skip_fc`).
    pub output_node: Option<usize>,
    /// Build provenance.
    pub meta: ArtifactMeta,
}

impl Artifact {
    /// Fingerprint of the config this artifact binds to.
    pub fn config_hash(&self) -> u64 {
        config_hash(&self.cfg)
    }

    /// The cost model's end-to-end cycle prediction: the sum of every
    /// layer's predicted cycles. The serving runtime derives its
    /// per-request deadline budget from this (`prediction × slack`).
    /// 0 means no layer carried a prediction — no deadline can be set.
    pub fn predicted_cycles(&self) -> u64 {
        self.compiled
            .plan
            .layers
            .iter()
            .map(|l| l.decision.predicted_cycles())
            .sum()
    }

    /// Identity fingerprint of the artifact itself: FNV-1a over the
    /// config fingerprint, the checksum of the encoded program words,
    /// the quantization format and the embedded model description.
    /// The format matters even though it never appears in an
    /// instruction word: weights are *quantized into the deployed
    /// image* with it, so a Q8.8 and a Q5.11 build of the same model
    /// produce identical programs but different DRAM images. Two
    /// artifacts with equal fingerprints deploy identical static
    /// images for the same weight seed — the cache key of the serving
    /// runtime's [`crate::engine::cache::ArtifactCache`].
    pub fn fingerprint(&self) -> u64 {
        let words = program_words(&self.compiled.program);
        let mut canon = Vec::with_capacity(24);
        canon.extend_from_slice(&self.config_hash().to_le_bytes());
        canon.extend_from_slice(&words_checksum(&words).to_le_bytes());
        canon.extend_from_slice(&(self.compiled.plan.fmt.frac as u64).to_le_bytes());
        canon.extend_from_slice(parser::dump_model(&self.graph).as_bytes());
        fnv1a(&canon)
    }

    /// Serialize to the on-disk JSON form.
    pub fn to_json(&self) -> Json {
        let words = program_words(&self.compiled.program);
        let comments: Vec<Json> = self
            .compiled
            .program
            .comments
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                c.as_ref().map(|s| Json::arr([Json::num(i as f64), Json::str(s)]))
            })
            .collect();
        let ranges: Vec<Json> = self
            .compiled
            .layer_ranges
            .iter()
            .map(|(li, name, r)| {
                Json::arr([
                    Json::num(*li as f64),
                    Json::str(name),
                    Json::num(r.start as f64),
                    Json::num(r.end as f64),
                ])
            })
            .collect();
        let schedules: Vec<(String, Json)> = self
            .schedules
            .iter()
            .map(|(node, s)| (node.to_string(), schedule_json(s)))
            .collect();
        Json::obj(vec![
            ("format", Json::str(FORMAT_MAGIC)),
            ("version", Json::num(FORMAT_VERSION as f64)),
            ("config_hash", Json::str(&hex(self.config_hash()))),
            ("config", config_json(&self.cfg)),
            ("model", Json::parse(&parser::dump_model(&self.graph)).expect("dump_model emits valid json")),
            ("meta", meta_json(&self.meta)),
            ("schedules", Json::Obj(schedules.into_iter().collect())),
            (
                "output_node",
                self.output_node.map(|n| Json::num(n as f64)).unwrap_or(Json::Null),
            ),
            ("code_len", Json::num(self.compiled.code_len as f64)),
            ("layer_ranges", Json::Arr(ranges)),
            (
                "program",
                Json::obj(vec![
                    ("checksum", Json::str(&hex(words_checksum(&words)))),
                    ("words", Json::arr(words.iter().map(|w| Json::num(*w as f64)))),
                    ("comments", Json::Arr(comments)),
                ]),
            ),
            ("plan", plan_json(&self.compiled.plan)),
        ])
    }

    /// Deserialize without config validation (inspection paths). Use
    /// [`Artifact::validate_config`] or [`Artifact::load`] before
    /// running the result on a machine.
    pub fn from_json(root: &Json) -> Result<Artifact, ArtifactError> {
        if root.get("format").as_str() != Some(FORMAT_MAGIC) {
            return Err(ArtifactError::NotAnArtifact);
        }
        let version = need_u64(root, "version")?;
        if version != FORMAT_VERSION {
            return Err(ArtifactError::FormatVersion { found: version, expected: FORMAT_VERSION });
        }
        let cfg = config_from(root.get("config"))?;
        // The recorded hash must match the recorded config: a mismatch
        // means the file was hand-edited or truncated mid-field.
        let recorded = root
            .get("config_hash")
            .as_str()
            .and_then(unhex)
            .ok_or_else(|| corrupt("config_hash missing or not hex"))?;
        if recorded != config_hash(&cfg) {
            return Err(corrupt("config_hash does not match the embedded config"));
        }
        let graph = parser::parse_model(&root.get("model").dump())
            .map_err(|e| corrupt(&format!("embedded model: {e}")))?;

        let pj = root.get("program");
        let words: Vec<u32> = pj
            .get("words")
            .as_arr()
            .ok_or_else(|| corrupt("program.words missing"))?
            .iter()
            .map(|w| {
                w.as_i64()
                    .and_then(|v| u32::try_from(v).ok())
                    .ok_or_else(|| corrupt("program word out of u32 range"))
            })
            .collect::<Result<_, _>>()?;
        let recorded_sum = pj
            .get("checksum")
            .as_str()
            .and_then(unhex)
            .ok_or_else(|| corrupt("program.checksum missing or not hex"))?;
        if recorded_sum != words_checksum(&words) {
            return Err(corrupt("program checksum mismatch (payload corrupted)"));
        }
        let mut program = Program::new();
        for (i, w) in words.iter().enumerate() {
            let instr = decode(*w).map_err(|e| corrupt(&format!("instruction {i}: {e}")))?;
            // Decode must be the exact inverse of the stored word —
            // anything else means the word was damaged in a way that
            // still decodes (flipped don't-care bits).
            if encode(&instr) != *w {
                return Err(corrupt(&format!("instruction {i} re-encodes differently")));
            }
            program.push(instr);
        }
        for c in pj.get("comments").as_arr().unwrap_or(&[]) {
            let i = c.idx(0).as_usize().ok_or_else(|| corrupt("comment index"))?;
            let s = c.idx(1).as_str().ok_or_else(|| corrupt("comment text"))?;
            if i >= program.comments.len() {
                return Err(corrupt("comment index beyond program length"));
            }
            program.comments[i] = Some(s.to_string());
        }

        let plan = plan_from(root.get("plan"))?;
        if plan.mem_words < plan.program_addr + 2 * program.len() {
            return Err(corrupt("plan.mem_words too small for the program image"));
        }
        validate_plan_bounds(&plan)?;
        let code_len = need(root, "code_len")?;
        let mut layer_ranges = Vec::new();
        for r in root
            .get("layer_ranges")
            .as_arr()
            .ok_or_else(|| corrupt("layer_ranges missing"))?
        {
            let li = r.idx(0).as_usize().ok_or_else(|| corrupt("layer_ranges idx"))?;
            let name = r.idx(1).as_str().ok_or_else(|| corrupt("layer_ranges name"))?;
            let s = r.idx(2).as_usize().ok_or_else(|| corrupt("layer_ranges start"))?;
            let e = r.idx(3).as_usize().ok_or_else(|| corrupt("layer_ranges end"))?;
            layer_ranges.push((li, name.to_string(), s..e));
        }
        let mut schedules = ScheduleMap::new();
        if let Some(map) = root.get("schedules").as_obj() {
            for (k, v) in map {
                let node: usize =
                    k.parse().map_err(|_| corrupt("schedule key is not a node id"))?;
                schedules.insert(node, schedule_from(v)?);
            }
        }
        let output_node = match root.get("output_node") {
            Json::Null => None,
            v => Some(v.as_usize().ok_or_else(|| corrupt("output_node"))?),
        };
        let meta = meta_from(root.get("meta"))?;
        Ok(Artifact {
            cfg,
            graph,
            compiled: CompiledModel { program, plan, layer_ranges, code_len },
            schedules,
            output_node,
            meta,
        })
    }

    /// Hard-error unless the artifact was compiled for `host`.
    pub fn validate_config(&self, host: &SnowflakeConfig) -> Result<(), ArtifactError> {
        if config_hash(&self.cfg) != config_hash(host) {
            return Err(ArtifactError::ConfigMismatch {
                artifact: hex(config_hash(&self.cfg)),
                host: hex(config_hash(host)),
            });
        }
        Ok(())
    }

    /// Write the artifact to `path` (pretty JSON).
    pub fn save(&self, path: &str) -> Result<(), ArtifactError> {
        std::fs::write(path, self.to_json().pretty() + "\n")
            .map_err(|e| ArtifactError::Io(format!("{path}: {e}")))
    }

    /// Read an artifact from `path` and validate it against the host
    /// configuration. Version, config-fingerprint or integrity failures
    /// are typed errors, never silent.
    pub fn load(path: &str, host: &SnowflakeConfig) -> Result<Artifact, ArtifactError> {
        let a = Self::load_unchecked(path)?;
        a.validate_config(host)?;
        Ok(a)
    }

    /// Read an artifact without binding it to a host config (inspection
    /// / cross-config tooling).
    pub fn load_unchecked(path: &str) -> Result<Artifact, ArtifactError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ArtifactError::Io(format!("{path}: {e}")))?;
        let root = Json::parse(&text).map_err(|e| ArtifactError::Parse(e.to_string()))?;
        Self::from_json(&root)
    }
}

/// Every memory region the plan names must fall inside `mem_words`:
/// a corrupted plan that passed the JSON grammar would otherwise panic
/// (slice out of bounds) or silently overwrite neighbouring regions at
/// deploy time — the failures this module promises are typed errors.
/// (u128 arithmetic: JSON numbers cap at 2^53, so products cannot be
/// made to wrap past the check.)
fn validate_plan_bounds(plan: &Plan) -> Result<(), ArtifactError> {
    let mem = plan.mem_words as u128;
    let check = |what: &str, base: usize, words: u128| -> Result<(), ArtifactError> {
        if base as u128 + words > mem {
            return Err(corrupt(&format!(
                "{what} region [{base}, +{words}) falls outside mem_words {}",
                plan.mem_words
            )));
        }
        Ok(())
    };
    let canvas_words = |c: &Canvas| {
        c.w_canvas() as u128 * c.h_canvas() as u128 * c.c_pad as u128
    };
    check("input canvas", plan.input_canvas.base, canvas_words(&plan.input_canvas))?;
    for (n, c) in &plan.canvases {
        check(&format!("canvas {n}"), c.base, canvas_words(c))?;
    }
    check("zero", plan.zero_addr, 64)?;
    for (i, lp) in plan.layers.iter().enumerate() {
        check(&format!("layer {i} weights"), lp.weights_addr, lp.weights_words as u128)?;
        check(&format!("layer {i} bias"), lp.bias_addr, lp.bias_words as u128)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Config fingerprint
// ---------------------------------------------------------------------

/// FNV-1a over a canonical field-by-field rendering of the config. Any
/// parameter change — and any schema change to `SnowflakeConfig`
/// itself, via the field list below — changes the fingerprint, which is
/// exactly the invalidation we want for compiled artifacts.
pub fn config_hash(c: &SnowflakeConfig) -> u64 {
    let canon = format!(
        "clock_mhz={};n_cus={};vmacs_per_cu={};macs_per_vmac={};word_bytes={};\
         mbuf_bank_bytes={};mbuf_banks={};wbuf_bytes={};bbuf_bytes={};\
         icache_banks={};icache_bank_instrs={};n_load_units={};axi_bytes_per_cycle={};\
         dma_setup_cycles={};link_bandwidth_gbs={};vector_queue_depth={};branch_delay_slots={};\
         scalar_exec_cycles={};gather_cycles={}",
        c.clock_mhz,
        c.n_cus,
        c.vmacs_per_cu,
        c.macs_per_vmac,
        c.word_bytes,
        c.mbuf_bank_bytes,
        c.mbuf_banks,
        c.wbuf_bytes,
        c.bbuf_bytes,
        c.icache_banks,
        c.icache_bank_instrs,
        c.n_load_units,
        c.axi_bytes_per_cycle,
        c.dma_setup_cycles,
        c.link_bandwidth_gbs,
        c.vector_queue_depth,
        c.branch_delay_slots,
        c.scalar_exec_cycles,
        c.gather_cycles
    );
    fnv1a(canon.as_bytes())
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn words_checksum(words: &[u32]) -> u64 {
    let mut bytes = Vec::with_capacity(words.len() * 4);
    for w in words {
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    fnv1a(&bytes)
}

fn program_words(p: &Program) -> Vec<u32> {
    p.instrs.iter().map(encode).collect()
}

pub(crate) fn hex(v: u64) -> String {
    format!("{v:016x}")
}

pub(crate) fn unhex(s: &str) -> Option<u64> {
    (s.len() == 16).then(|| u64::from_str_radix(s, 16).ok()).flatten()
}

fn corrupt(msg: &str) -> ArtifactError {
    ArtifactError::Corrupt(msg.to_string())
}

fn need(j: &Json, key: &str) -> Result<usize, ArtifactError> {
    j.get(key).as_usize().ok_or_else(|| corrupt(&format!("missing/invalid field '{key}'")))
}

fn need_u64(j: &Json, key: &str) -> Result<u64, ArtifactError> {
    j.get(key)
        .as_i64()
        .and_then(|v| u64::try_from(v).ok())
        .ok_or_else(|| corrupt(&format!("missing/invalid field '{key}'")))
}

fn need_bool(j: &Json, key: &str) -> Result<bool, ArtifactError> {
    j.get(key).as_bool().ok_or_else(|| corrupt(&format!("missing/invalid field '{key}'")))
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, ArtifactError> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => Ok(Some(v.as_usize().ok_or_else(|| corrupt(&format!("field '{key}'")))?)),
    }
}

fn ju(n: usize) -> Json {
    Json::Num(n as f64)
}

fn ju64(n: u64) -> Json {
    Json::Num(n as f64)
}

fn jopt(n: Option<usize>) -> Json {
    n.map(ju).unwrap_or(Json::Null)
}

// ---------------------------------------------------------------------
// Config / meta / schedule codecs
// ---------------------------------------------------------------------

pub(crate) fn config_json(c: &SnowflakeConfig) -> Json {
    Json::obj(vec![
        ("clock_mhz", Json::Num(c.clock_mhz)),
        ("n_cus", ju(c.n_cus)),
        ("vmacs_per_cu", ju(c.vmacs_per_cu)),
        ("macs_per_vmac", ju(c.macs_per_vmac)),
        ("word_bytes", ju(c.word_bytes)),
        ("mbuf_bank_bytes", ju(c.mbuf_bank_bytes)),
        ("mbuf_banks", ju(c.mbuf_banks)),
        ("wbuf_bytes", ju(c.wbuf_bytes)),
        ("bbuf_bytes", ju(c.bbuf_bytes)),
        ("icache_banks", ju(c.icache_banks)),
        ("icache_bank_instrs", ju(c.icache_bank_instrs)),
        ("n_load_units", ju(c.n_load_units)),
        ("axi_bytes_per_cycle", Json::Num(c.axi_bytes_per_cycle)),
        ("dma_setup_cycles", ju64(c.dma_setup_cycles)),
        ("link_bandwidth_gbs", Json::Num(c.link_bandwidth_gbs)),
        ("vector_queue_depth", ju(c.vector_queue_depth)),
        ("branch_delay_slots", ju(c.branch_delay_slots)),
        ("scalar_exec_cycles", ju64(c.scalar_exec_cycles)),
        ("gather_cycles", ju64(c.gather_cycles)),
    ])
}

pub(crate) fn config_from(j: &Json) -> Result<SnowflakeConfig, ArtifactError> {
    let f = |key: &str| -> Result<f64, ArtifactError> {
        j.get(key).as_f64().ok_or_else(|| corrupt(&format!("config.{key}")))
    };
    Ok(SnowflakeConfig {
        clock_mhz: f("clock_mhz")?,
        n_cus: need(j, "n_cus")?,
        vmacs_per_cu: need(j, "vmacs_per_cu")?,
        macs_per_vmac: need(j, "macs_per_vmac")?,
        word_bytes: need(j, "word_bytes")?,
        mbuf_bank_bytes: need(j, "mbuf_bank_bytes")?,
        mbuf_banks: need(j, "mbuf_banks")?,
        wbuf_bytes: need(j, "wbuf_bytes")?,
        bbuf_bytes: need(j, "bbuf_bytes")?,
        icache_banks: need(j, "icache_banks")?,
        icache_bank_instrs: need(j, "icache_bank_instrs")?,
        n_load_units: need(j, "n_load_units")?,
        axi_bytes_per_cycle: f("axi_bytes_per_cycle")?,
        dma_setup_cycles: need_u64(j, "dma_setup_cycles")?,
        link_bandwidth_gbs: f("link_bandwidth_gbs")?,
        vector_queue_depth: need(j, "vector_queue_depth")?,
        branch_delay_slots: need(j, "branch_delay_slots")?,
        scalar_exec_cycles: need_u64(j, "scalar_exec_cycles")?,
        gather_cycles: need_u64(j, "gather_cycles")?,
    })
}

fn meta_json(m: &ArtifactMeta) -> Json {
    Json::obj(vec![
        ("tune", Json::str(&m.tune)),
        ("balance", Json::str(&m.balance)),
        ("smart_delay_slots", Json::Bool(m.smart_delay_slots)),
        ("reuse_regions", Json::Bool(m.reuse_regions)),
        ("skip_fc", Json::Bool(m.skip_fc)),
    ])
}

fn meta_from(j: &Json) -> Result<ArtifactMeta, ArtifactError> {
    Ok(ArtifactMeta {
        tune: j.get("tune").as_str().unwrap_or("?").to_string(),
        balance: j.get("balance").as_str().unwrap_or("?").to_string(),
        smart_delay_slots: need_bool(j, "smart_delay_slots")?,
        reuse_regions: need_bool(j, "reuse_regions")?,
        skip_fc: need_bool(j, "skip_fc")?,
    })
}

fn policy_str(p: BalancePolicy) -> String {
    match p {
        BalancePolicy::Greedy { split } => format!("greedy{split}"),
        BalancePolicy::TwoUnits => "two-units".to_string(),
        BalancePolicy::OneUnit => "one-unit".to_string(),
    }
}

fn policy_json(p: BalancePolicy) -> Json {
    match p {
        BalancePolicy::Greedy { split } => {
            Json::obj(vec![("kind", Json::str("greedy")), ("split", ju(split))])
        }
        BalancePolicy::TwoUnits => Json::obj(vec![("kind", Json::str("two-units"))]),
        BalancePolicy::OneUnit => Json::obj(vec![("kind", Json::str("one-unit"))]),
    }
}

fn policy_from(j: &Json) -> Result<BalancePolicy, ArtifactError> {
    match j.get("kind").as_str() {
        Some("greedy") => Ok(BalancePolicy::Greedy { split: need(j, "split")? }),
        Some("two-units") => Ok(BalancePolicy::TwoUnits),
        Some("one-unit") => Ok(BalancePolicy::OneUnit),
        _ => Err(corrupt("unknown balance policy")),
    }
}

fn order_str(o: LoopOrder) -> &'static str {
    match o {
        LoopOrder::Mloop => "mloop",
        LoopOrder::Kloop => "kloop",
        LoopOrder::MloopRot => "mloop-rot",
    }
}

fn order_from(j: &Json) -> Result<LoopOrder, ArtifactError> {
    match j.as_str() {
        Some("mloop") => Ok(LoopOrder::Mloop),
        Some("kloop") => Ok(LoopOrder::Kloop),
        Some("mloop-rot") => Ok(LoopOrder::MloopRot),
        // Any other order came from a different (future) format or a
        // damaged file — typed rejection, never a silent Kloop.
        _ => Err(corrupt("unknown loop order")),
    }
}

fn schedule_json(s: &Schedule) -> Json {
    Json::obj(vec![
        ("order", Json::str(order_str(s.order))),
        ("rows_per_cu", ju(s.rows_per_cu)),
        ("policy", policy_json(s.policy)),
    ])
}

fn schedule_from(j: &Json) -> Result<Schedule, ArtifactError> {
    Ok(Schedule {
        order: order_from(j.get("order"))?,
        rows_per_cu: need(j, "rows_per_cu")?,
        policy: policy_from(j.get("policy"))?,
    })
}

// ---------------------------------------------------------------------
// Plan codec
// ---------------------------------------------------------------------

fn canvas_json(c: &Canvas) -> Json {
    Json::obj(vec![
        ("base", ju(c.base)),
        ("c", ju(c.c)),
        ("h", ju(c.h)),
        ("w", ju(c.w)),
        ("c_pad", ju(c.c_pad)),
        ("mp", ju(c.mp)),
        ("h_slack", ju(c.h_slack)),
        ("w_slack", ju(c.w_slack)),
    ])
}

fn canvas_from(j: &Json) -> Result<Canvas, ArtifactError> {
    Ok(Canvas {
        base: need(j, "base")?,
        c: need(j, "c")?,
        h: need(j, "h")?,
        w: need(j, "w")?,
        c_pad: need(j, "c_pad")?,
        mp: need(j, "mp")?,
        h_slack: need(j, "h_slack")?,
        w_slack: need(j, "w_slack")?,
    })
}

fn lowered_json(op: &Lowered) -> Json {
    match *op {
        Lowered::Conv { node, src, bypass, in_ch, out_ch, kh, kw, stride, pad, relu } => {
            Json::obj(vec![
                ("kind", Json::str("conv")),
                ("node", ju(node)),
                ("src", jopt(src)),
                ("bypass", jopt(bypass)),
                ("in_ch", ju(in_ch)),
                ("out_ch", ju(out_ch)),
                ("kh", ju(kh)),
                ("kw", ju(kw)),
                ("stride", ju(stride)),
                ("pad", ju(pad)),
                ("relu", Json::Bool(relu)),
            ])
        }
        Lowered::MaxPool { node, src, kh, kw, stride, pad } => Json::obj(vec![
            ("kind", Json::str("maxpool")),
            ("node", ju(node)),
            ("src", jopt(src)),
            ("kh", ju(kh)),
            ("kw", ju(kw)),
            ("stride", ju(stride)),
            ("pad", ju(pad)),
        ]),
        Lowered::AvgPool { node, src, kh, kw, stride, pad } => Json::obj(vec![
            ("kind", Json::str("avgpool")),
            ("node", ju(node)),
            ("src", jopt(src)),
            ("kh", ju(kh)),
            ("kw", ju(kw)),
            ("stride", ju(stride)),
            ("pad", ju(pad)),
        ]),
        Lowered::Fc { node, src, in_features, out_features, relu } => Json::obj(vec![
            ("kind", Json::str("fc")),
            ("node", ju(node)),
            ("src", jopt(src)),
            ("in_features", ju(in_features)),
            ("out_features", ju(out_features)),
            ("relu", Json::Bool(relu)),
        ]),
    }
}

fn lowered_from(j: &Json) -> Result<Lowered, ArtifactError> {
    match j.get("kind").as_str() {
        Some("conv") => Ok(Lowered::Conv {
            node: need(j, "node")?,
            src: opt_usize(j, "src")?,
            bypass: opt_usize(j, "bypass")?,
            in_ch: need(j, "in_ch")?,
            out_ch: need(j, "out_ch")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
            relu: need_bool(j, "relu")?,
        }),
        Some("maxpool") => Ok(Lowered::MaxPool {
            node: need(j, "node")?,
            src: opt_usize(j, "src")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
        }),
        Some("avgpool") => Ok(Lowered::AvgPool {
            node: need(j, "node")?,
            src: opt_usize(j, "src")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
        }),
        Some("fc") => Ok(Lowered::Fc {
            node: need(j, "node")?,
            src: opt_usize(j, "src")?,
            in_features: need(j, "in_features")?,
            out_features: need(j, "out_features")?,
            relu: need_bool(j, "relu")?,
        }),
        _ => Err(corrupt("unknown lowered-op kind")),
    }
}

fn estimate_json(e: &CostEstimate) -> Json {
    Json::obj(vec![
        ("cycles", ju64(e.cycles)),
        ("dram_bytes", ju64(e.dram_bytes)),
        ("compute_cycles", ju64(e.compute_cycles)),
        ("issue_cycles", ju64(e.issue_cycles)),
        ("dma_cycles", ju64(e.dma_cycles)),
        ("startup_cycles", ju64(e.startup_cycles)),
        ("streams", ju64(e.streams)),
    ])
}

fn estimate_from(j: &Json) -> Result<CostEstimate, ArtifactError> {
    Ok(CostEstimate {
        cycles: need_u64(j, "cycles")?,
        dram_bytes: need_u64(j, "dram_bytes")?,
        compute_cycles: need_u64(j, "compute_cycles")?,
        issue_cycles: need_u64(j, "issue_cycles")?,
        dma_cycles: need_u64(j, "dma_cycles")?,
        startup_cycles: need_u64(j, "startup_cycles")?,
        streams: need_u64(j, "streams")?,
    })
}

fn geom_json(g: &Geom) -> Json {
    Json::obj(vec![
        ("row_read", ju(g.row_read)),
        ("segs", Json::arr(g.segs.iter().map(|s| ju(*s)))),
        ("in_w_slack", ju(g.in_w_slack)),
    ])
}

fn geom_from(j: &Json) -> Result<Geom, ArtifactError> {
    Ok(Geom {
        row_read: need(j, "row_read")?,
        segs: j
            .get("segs")
            .as_arr()
            .ok_or_else(|| corrupt("geom.segs"))?
            .iter()
            .map(|s| s.as_usize().ok_or_else(|| corrupt("geom.segs entry")))
            .collect::<Result<_, _>>()?,
        in_w_slack: need(j, "in_w_slack")?,
    })
}

fn decision_json(d: &OpPlan) -> Json {
    match d {
        OpPlan::Conv(c) => Json::obj(vec![
            ("kind", Json::str("conv")),
            ("c_pad_in", ju(c.c_pad_in)),
            ("c_pad_out", ju(c.c_pad_out)),
            ("kh", ju(c.kh)),
            ("kw", ju(c.kw)),
            ("stride", ju(c.stride)),
            ("pad", ju(c.pad)),
            ("h_out", ju(c.h_out)),
            ("w_out", ju(c.w_out)),
            ("geom", geom_json(&c.geom)),
            ("kernel_words", ju(c.kernel_words)),
            ("k_groups", ju(c.k_groups)),
            ("rows_per_cu", ju(c.rows_per_cu)),
            ("n_tiles", ju(c.n_tiles)),
            ("order", Json::str(order_str(c.order))),
            ("split", ju(c.split)),
            ("policy", policy_json(c.policy)),
            ("max_rows", ju(c.max_rows)),
            ("predicted", estimate_json(&c.predicted)),
            ("dbuf_w", Json::Bool(c.dbuf_w)),
            ("has_bypass", Json::Bool(c.has_bypass)),
            ("relu", Json::Bool(c.relu)),
        ]),
        OpPlan::MaxPool(p) => Json::obj(vec![
            ("kind", Json::str("maxpool")),
            ("c", ju(p.c)),
            ("c_pad", ju(p.c_pad)),
            ("kh", ju(p.kh)),
            ("kw", ju(p.kw)),
            ("stride", ju(p.stride)),
            ("pad", ju(p.pad)),
            ("h_out", ju(p.h_out)),
            ("w_out", ju(p.w_out)),
            ("x_groups", ju(p.x_groups)),
            ("rows_per_cu", ju(p.rows_per_cu)),
            ("n_tiles", ju(p.n_tiles)),
            ("spill", ju(p.spill)),
            ("max_rows", ju(p.max_rows)),
            ("predicted", estimate_json(&p.predicted)),
        ]),
        OpPlan::AvgPool(a) => Json::obj(vec![
            ("kind", Json::str("avgpool")),
            ("c", ju(a.c)),
            ("c_pad", ju(a.c_pad)),
            ("kh", ju(a.kh)),
            ("kw", ju(a.kw)),
            ("stride", ju(a.stride)),
            ("h_out", ju(a.h_out)),
            ("w_out", ju(a.w_out)),
            ("chunks", ju(a.chunks)),
        ]),
        OpPlan::Fc(f) => Json::obj(vec![
            ("kind", Json::str("fc")),
            ("in_features", ju(f.in_features)),
            ("out_features", ju(f.out_features)),
            ("k_groups", ju(f.k_groups)),
            ("chunks", Json::arr(f.chunks.iter().map(|c| ju(*c)))),
            ("relu", Json::Bool(f.relu)),
        ]),
    }
}

fn decision_from(j: &Json) -> Result<OpPlan, ArtifactError> {
    match j.get("kind").as_str() {
        Some("conv") => Ok(OpPlan::Conv(ConvPlan {
            c_pad_in: need(j, "c_pad_in")?,
            c_pad_out: need(j, "c_pad_out")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
            h_out: need(j, "h_out")?,
            w_out: need(j, "w_out")?,
            geom: geom_from(j.get("geom"))?,
            kernel_words: need(j, "kernel_words")?,
            k_groups: need(j, "k_groups")?,
            rows_per_cu: need(j, "rows_per_cu")?,
            n_tiles: need(j, "n_tiles")?,
            order: order_from(j.get("order"))?,
            split: need(j, "split")?,
            policy: policy_from(j.get("policy"))?,
            max_rows: need(j, "max_rows")?,
            predicted: estimate_from(j.get("predicted"))?,
            dbuf_w: need_bool(j, "dbuf_w")?,
            has_bypass: need_bool(j, "has_bypass")?,
            relu: need_bool(j, "relu")?,
        })),
        Some("maxpool") => Ok(OpPlan::MaxPool(PoolPlan {
            c: need(j, "c")?,
            c_pad: need(j, "c_pad")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
            h_out: need(j, "h_out")?,
            w_out: need(j, "w_out")?,
            x_groups: need(j, "x_groups")?,
            rows_per_cu: need(j, "rows_per_cu")?,
            n_tiles: need(j, "n_tiles")?,
            spill: need(j, "spill")?,
            max_rows: need(j, "max_rows")?,
            predicted: estimate_from(j.get("predicted"))?,
        })),
        Some("avgpool") => Ok(OpPlan::AvgPool(AvgPlan {
            c: need(j, "c")?,
            c_pad: need(j, "c_pad")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            h_out: need(j, "h_out")?,
            w_out: need(j, "w_out")?,
            chunks: need(j, "chunks")?,
        })),
        Some("fc") => Ok(OpPlan::Fc(FcPlan {
            in_features: need(j, "in_features")?,
            out_features: need(j, "out_features")?,
            k_groups: need(j, "k_groups")?,
            chunks: j
                .get("chunks")
                .as_arr()
                .ok_or_else(|| corrupt("fc.chunks"))?
                .iter()
                .map(|c| c.as_usize().ok_or_else(|| corrupt("fc.chunks entry")))
                .collect::<Result<_, _>>()?,
            relu: need_bool(j, "relu")?,
        })),
        _ => Err(corrupt("unknown decision kind")),
    }
}

fn plan_json(p: &Plan) -> Json {
    let canvases: BTreeMap<String, Json> =
        p.canvases.iter().map(|(n, c)| (n.to_string(), canvas_json(c))).collect();
    let layers: Vec<Json> = p
        .layers
        .iter()
        .map(|lp| {
            Json::obj(vec![
                ("op", lowered_json(&lp.op)),
                ("decision", decision_json(&lp.decision)),
                ("weights_addr", ju(lp.weights_addr)),
                ("weights_words", ju(lp.weights_words)),
                ("bias_addr", ju(lp.bias_addr)),
                ("bias_words", ju(lp.bias_words)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("fmt_frac", ju(p.fmt.frac as usize)),
        ("input_canvas", canvas_json(&p.input_canvas)),
        ("canvases", Json::Obj(canvases)),
        ("layers", Json::Arr(layers)),
        ("zero_addr", ju(p.zero_addr)),
        ("program_addr", ju(p.program_addr)),
        ("mem_words", ju(p.mem_words)),
        ("activation_words", ju(p.activation_words)),
    ])
}

fn plan_from(j: &Json) -> Result<Plan, ArtifactError> {
    let frac = need(j, "fmt_frac")?;
    if frac >= 16 {
        return Err(corrupt("fmt_frac out of range"));
    }
    let mut canvases = BTreeMap::new();
    if let Some(map) = j.get("canvases").as_obj() {
        for (k, v) in map {
            let node: usize = k.parse().map_err(|_| corrupt("canvas key"))?;
            canvases.insert(node, canvas_from(v)?);
        }
    }
    let mut layers = Vec::new();
    for l in j.get("layers").as_arr().ok_or_else(|| corrupt("plan.layers"))? {
        layers.push(LayerPlan {
            op: lowered_from(l.get("op"))?,
            decision: decision_from(l.get("decision"))?,
            weights_addr: need(l, "weights_addr")?,
            weights_words: need(l, "weights_words")?,
            bias_addr: need(l, "bias_addr")?,
            bias_words: need(l, "bias_words")?,
        });
    }
    Ok(Plan {
        fmt: QFormat::new(frac as u32),
        input_canvas: canvas_from(j.get("input_canvas"))?,
        canvases,
        layers,
        zero_addr: need(j, "zero_addr")?,
        program_addr: need(j, "program_addr")?,
        mem_words: need(j, "mem_words")?,
        activation_words: need(j, "activation_words")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::Compiler;
    use crate::model::layer::{LayerKind, Shape};

    fn small_graph() -> Graph {
        let mut g = Graph::new("artifact_small", Shape::new(16, 12, 12));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c1",
        );
        g.push_seq(LayerKind::MaxPool { kh: 2, kw: 2, stride: 2, pad: 0 }, "p1");
        g
    }

    fn build_small() -> Artifact {
        Compiler::new(SnowflakeConfig::default())
            .build(&small_graph())
            .expect("build")
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let a = build_small();
        let back = Artifact::from_json(&a.to_json()).expect("roundtrip");
        assert_eq!(back.compiled.program, a.compiled.program, "program must round-trip exactly");
        assert_eq!(back.compiled.plan, a.compiled.plan, "plan must round-trip exactly");
        assert_eq!(back.compiled.layer_ranges, a.compiled.layer_ranges);
        assert_eq!(back.compiled.code_len, a.compiled.code_len);
        assert_eq!(back.schedules, a.schedules);
        assert_eq!(back.output_node, a.output_node);
        assert_eq!(back.meta, a.meta);
        assert_eq!(back.cfg, a.cfg);
        assert_eq!(back.graph.nodes.len(), a.graph.nodes.len());
        // Re-serialization is stable (byte-identical text).
        assert_eq!(back.to_json().pretty(), a.to_json().pretty());
    }

    #[test]
    fn fingerprint_distinguishes_quantization_formats() {
        // Q8.8 and Q5.11 builds of the same model emit identical
        // program words but deploy differently-quantized weight
        // images; the cache key must tell them apart.
        let g = small_graph();
        let cfg = SnowflakeConfig::default();
        let a8 = Compiler::new(cfg.clone()).build(&g).unwrap();
        let a11 = Compiler::new(cfg)
            .options(CompileOptions { fmt: crate::fixed::Q5_11, ..Default::default() })
            .build(&g)
            .unwrap();
        assert_ne!(a8.fingerprint(), a11.fingerprint());
        // Stable across clones of the same artifact.
        assert_eq!(a8.fingerprint(), a8.clone().fingerprint());
    }

    #[test]
    fn config_hash_is_stable_and_sensitive() {
        let c = SnowflakeConfig::default();
        assert_eq!(config_hash(&c), config_hash(&c.clone()));
        let c2 = SnowflakeConfig { n_cus: 8, ..c.clone() };
        assert_ne!(config_hash(&c), config_hash(&c2));
        let c3 = SnowflakeConfig { dma_setup_cycles: 65, ..c.clone() };
        assert_ne!(config_hash(&c3), config_hash(&SnowflakeConfig::default()));
        // v3: the inter-stage link bandwidth is part of the schema, so a
        // different link speed invalidates compiled artifacts too.
        let c4 = SnowflakeConfig { link_bandwidth_gbs: 2.0, ..c };
        assert_ne!(config_hash(&c4), config_hash(&SnowflakeConfig::default()));
    }

    #[test]
    fn config_mismatch_is_a_hard_typed_error() {
        let a = build_small();
        let other = SnowflakeConfig { mbuf_bank_bytes: 32 * 1024, ..SnowflakeConfig::default() };
        let err = a.validate_config(&other).unwrap_err();
        assert!(matches!(err, ArtifactError::ConfigMismatch { .. }), "{err}");
        assert!(a.validate_config(&SnowflakeConfig::default()).is_ok());
    }

    #[test]
    fn version_mismatch_rejected() {
        let a = build_small();
        let mut j = a.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::num(99));
        }
        let err = Artifact::from_json(&j).unwrap_err();
        assert_eq!(
            err,
            ArtifactError::FormatVersion { found: 99, expected: FORMAT_VERSION }
        );
    }

    #[test]
    fn v1_artifacts_rejected_with_typed_error() {
        // Pre-rotation artifacts (format v1) predate the `mloop-rot`
        // order and its cost model: loading one must be a typed
        // FormatVersion error, not a best-effort parse.
        let a = build_small();
        let mut j = a.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::num(1.0));
        }
        let err = Artifact::from_json(&j).unwrap_err();
        assert_eq!(err, ArtifactError::FormatVersion { found: 1, expected: FORMAT_VERSION });
    }

    #[test]
    fn v2_artifacts_rejected_with_typed_error() {
        // Format-v2 artifacts predate `link_bandwidth_gbs` in the
        // config schema: their config hash was computed without the
        // field, so loading one must be a typed FormatVersion error
        // ("rebuild"), not a baffling config-mismatch hex pair.
        let a = build_small();
        let mut j = a.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::num(2.0));
        }
        let err = Artifact::from_json(&j).unwrap_err();
        assert_eq!(err, ArtifactError::FormatVersion { found: 2, expected: FORMAT_VERSION });
    }

    #[test]
    fn unknown_loop_order_rejected_on_load() {
        assert!(order_from(&Json::str("mloop")).is_ok());
        assert!(order_from(&Json::str("mloop-rot")).is_ok());
        let err = order_from(&Json::str("zloop")).unwrap_err();
        assert!(matches!(err, ArtifactError::Corrupt(_)), "{err}");
        // Round-trip of every order string.
        for o in [LoopOrder::Kloop, LoopOrder::Mloop, LoopOrder::MloopRot] {
            assert_eq!(order_from(&Json::str(order_str(o))).unwrap(), o);
        }
    }

    #[test]
    fn corrupted_program_word_rejected() {
        let a = build_small();
        let mut j = a.to_json();
        // Flip one program word without updating the checksum.
        if let Json::Obj(o) = &mut j {
            let p = o.get_mut("program").unwrap();
            if let Json::Obj(po) = p {
                if let Some(Json::Arr(words)) = po.get_mut("words") {
                    words[3] = Json::num(0x1234_5678u32 as f64);
                }
            }
        }
        let err = Artifact::from_json(&j).unwrap_err();
        assert!(matches!(err, ArtifactError::Corrupt(_)), "{err}");
    }

    #[test]
    fn non_artifact_json_rejected() {
        let err = Artifact::from_json(&Json::parse(r#"{"hello": 1}"#).unwrap()).unwrap_err();
        assert_eq!(err, ArtifactError::NotAnArtifact);
    }

    #[test]
    fn hex_helpers_roundtrip() {
        for v in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(unhex(&hex(v)), Some(v));
        }
        assert_eq!(unhex("xyz"), None);
        assert_eq!(unhex("123"), None); // wrong length
    }
}
