//! Versioned, serializable compiled artifacts — the §5.3 deployment
//! product as a first-class object.
//!
//! The paper's compiler exists to be *deployed*: instructions and data
//! are arranged once and then executed many times on the accelerator
//! (§5.3 "Instruction deployment"). An [`Artifact`] captures everything
//! a runtime needs to do that without re-running the compiler:
//!
//! * the encoded instruction [`Program`] (with its assembler comments,
//!   so a loaded artifact disassembles identically),
//! * the full memory [`Plan`] — canvases, weight/bias placement, the
//!   program image address — down to every per-layer `OpPlan` decision,
//! * the chosen per-conv-layer [`Schedule`]s (replayable through
//!   [`CompileOptions::schedules`]),
//! * the model description itself (the `model/parser.rs` JSON form), so
//!   the runtime can synthesize/arrange weights and inputs,
//! * provenance: compiler options, the [`FORMAT_VERSION`], and a
//!   **config fingerprint** of the [`SnowflakeConfig`] the artifact was
//!   compiled for.
//!
//! Loading validates the format version, the config fingerprint against
//! the *loading* machine's configuration, and an FNV-1a checksum over
//! the encoded instruction words — a wrong hardware config or a
//! corrupted payload is a typed [`ArtifactError`], never a silent
//! miscompute. The on-disk form is JSON via `util/json.rs` (the repo is
//! dependency-free; see rust/Cargo.toml), self-describing and diffable.
//!
//! The full build → save → load → run cycle:
//!
//! ```ignore
//! let artifact = Compiler::new(cfg.clone()).options(opts).build(&graph)?;
//! artifact.save("model.artifact.json")?;                     // `repro build`
//! let back = Artifact::load("model.artifact.json", &cfg)?;   // validated
//! let mut engine = Engine::new(cfg);                         // `repro run --artifact`
//! let h = engine.load(back, seed)?;
//! let out = engine.infer(h, &input)?;                        // == compile-and-run, exactly
//! ```

use super::cost::{CostEstimate, Schedule};
use super::decide::{AvgPlan, ConvPlan, FcPlan, Geom, OpPlan, PoolPlan};
use super::layout::{Canvas, LayerPlan, Lowered, Plan};
use super::{BalancePolicy, CompileOptions, CompiledModel, LoopOrder, ScheduleMap, TuneMode};
use crate::arch::SnowflakeConfig;
use crate::fixed::QFormat;
use crate::isa::encode::{decode, encode};
use crate::isa::instr::Program;
use crate::model::graph::Graph;
use crate::model::parser;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// On-disk artifact format version. Bump on any incompatible change to
/// the serialized layout; loaders hard-error on mismatch.
///
/// v2 (ISSUE 5): the banked-rotation loop order (`"mloop-rot"`) joined
/// the `Schedule`/`ConvPlan` codecs. v1 readers would reject the new
/// order string as corrupt, and v1 artifacts predate the rotation
/// skeleton's cost model, so both directions hard-error on the version
/// instead of guessing.
///
/// v3 (ISSUE 8): `link_bandwidth_gbs` joined the `SnowflakeConfig`
/// schema — and therefore the config fingerprint. A v2 artifact's hash
/// was computed without the field, so it can never match a v3 host's;
/// rejecting on the version gives the typed "rebuild" message instead
/// of a confusing config-mismatch hex pair.
pub const FORMAT_VERSION: u64 = 3;

/// Magic tag identifying an artifact file.
pub const FORMAT_MAGIC: &str = "snowflake-artifact";

/// Magic prefix of the binary envelope. The first byte can never be
/// `{` (or leading whitespace), so [`Artifact::from_bytes`] can sniff
/// the encoding from content alone — extensions are advisory.
pub const BIN_MAGIC: [u8; 8] = *b"SNFLKART";

/// On-disk encoding of an artifact. Both carry the same
/// `FORMAT_VERSION` / config-fingerprint / checksum discipline and load
/// through the same sniffing [`Artifact::load`]; `Bin` is the compact
/// length-prefixed envelope (see `to_bin`), `Json` the self-describing
/// pretty form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactFormat {
    Json,
    Bin,
}

impl ArtifactFormat {
    /// File extension conventionally used for this encoding
    /// (`.artifact.json` / `.artifact.bin`). Loaders never trust it;
    /// the sniffer decides from content.
    pub fn extension(self) -> &'static str {
        match self {
            ArtifactFormat::Json => "json",
            ArtifactFormat::Bin => "bin",
        }
    }

    /// Parse a CLI/manifest token.
    pub fn parse(s: &str) -> Option<ArtifactFormat> {
        match s {
            "json" => Some(ArtifactFormat::Json),
            "bin" | "binary" => Some(ArtifactFormat::Bin),
            _ => None,
        }
    }
}

impl std::fmt::Display for ArtifactFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ArtifactFormat::Json => "json",
            ArtifactFormat::Bin => "bin",
        })
    }
}

/// Why an artifact could not be saved or loaded.
#[derive(Debug, Clone, PartialEq)]
pub enum ArtifactError {
    /// Filesystem failure (path included in the message).
    Io(String),
    /// The payload is not valid JSON or is missing required fields.
    Parse(String),
    /// Not an artifact file at all (magic tag mismatch).
    NotAnArtifact,
    /// The artifact was written by an incompatible format version.
    FormatVersion { found: u64, expected: u64 },
    /// The artifact was compiled for different hardware: running it on
    /// this configuration would silently miscompute addresses/timing.
    ConfigMismatch { artifact: String, host: String },
    /// The payload decoded but failed an integrity check (checksum,
    /// instruction decode, internal consistency).
    Corrupt(String),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io(m) => write!(f, "artifact io error: {m}"),
            ArtifactError::Parse(m) => write!(f, "artifact parse error: {m}"),
            ArtifactError::NotAnArtifact => {
                write!(f, "not a snowflake artifact (magic tag missing)")
            }
            ArtifactError::FormatVersion { found, expected } => write!(
                f,
                "artifact format version {found} is not supported (expected {expected}); \
                 rebuild the artifact with `repro build`"
            ),
            ArtifactError::ConfigMismatch { artifact, host } => write!(
                f,
                "artifact was compiled for config {artifact} but this machine is {host}; \
                 rebuild the artifact for this hardware configuration"
            ),
            ArtifactError::Corrupt(m) => write!(f, "artifact corrupt: {m}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

/// Compiler provenance recorded in the artifact (informational; the
/// binding facts — program, plan, schedules — are stored explicitly).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// `TuneMode` the artifact was built under (display form).
    pub tune: String,
    /// Base balance policy (display form).
    pub balance: String,
    pub smart_delay_slots: bool,
    pub reuse_regions: bool,
    pub skip_fc: bool,
}

impl ArtifactMeta {
    pub fn of(opts: &CompileOptions) -> Self {
        let tune = match opts.tune {
            TuneMode::Heuristic => "heuristic".to_string(),
            TuneMode::Analytical => "analytical".to_string(),
            TuneMode::Measured { top_k } => format!("measured(top_k={top_k})"),
        };
        ArtifactMeta {
            tune,
            balance: policy_str(opts.balance),
            smart_delay_slots: opts.smart_delay_slots,
            reuse_regions: opts.reuse_regions,
            skip_fc: opts.skip_fc,
        }
    }
}

/// A versioned compiled artifact: everything `build` produced, ready to
/// save/load and to hand to the [`crate::engine::Engine`].
#[derive(Clone, Debug)]
pub struct Artifact {
    /// The hardware configuration the program was compiled for.
    pub cfg: SnowflakeConfig,
    /// The model graph (embedded so the artifact is self-contained).
    pub graph: Graph,
    /// Program + memory plan + layer ranges (the compile output).
    pub compiled: CompiledModel,
    /// Chosen per-conv-layer schedules, keyed by lowered node id —
    /// replayable through [`CompileOptions::schedules`].
    pub schedules: ScheduleMap,
    /// Node whose canvas holds the final generated output (None when
    /// every layer was skipped, e.g. an all-FC model under `skip_fc`).
    pub output_node: Option<usize>,
    /// Build provenance.
    pub meta: ArtifactMeta,
}

impl Artifact {
    /// Fingerprint of the config this artifact binds to.
    pub fn config_hash(&self) -> u64 {
        config_hash(&self.cfg)
    }

    /// The cost model's end-to-end cycle prediction: the sum of every
    /// layer's predicted cycles. The serving runtime derives its
    /// per-request deadline budget from this (`prediction × slack`).
    /// 0 means no layer carried a prediction — no deadline can be set.
    pub fn predicted_cycles(&self) -> u64 {
        self.compiled
            .plan
            .layers
            .iter()
            .map(|l| l.decision.predicted_cycles())
            .sum()
    }

    /// Identity fingerprint of the artifact itself: FNV-1a over the
    /// config fingerprint, the checksum of the encoded program words,
    /// the quantization format and the embedded model description.
    /// The format matters even though it never appears in an
    /// instruction word: weights are *quantized into the deployed
    /// image* with it, so a Q8.8 and a Q5.11 build of the same model
    /// produce identical programs but different DRAM images. Two
    /// artifacts with equal fingerprints deploy identical static
    /// images for the same weight seed — the cache key of the serving
    /// runtime's [`crate::engine::cache::ArtifactCache`].
    pub fn fingerprint(&self) -> u64 {
        let words = program_words(&self.compiled.program);
        let mut canon = Vec::with_capacity(24);
        canon.extend_from_slice(&self.config_hash().to_le_bytes());
        canon.extend_from_slice(&words_checksum(&words).to_le_bytes());
        canon.extend_from_slice(&(self.compiled.plan.fmt.frac as u64).to_le_bytes());
        canon.extend_from_slice(parser::dump_model(&self.graph).as_bytes());
        fnv1a(&canon)
    }

    /// Serialize to the on-disk JSON form.
    pub fn to_json(&self) -> Json {
        let words = program_words(&self.compiled.program);
        let comments: Vec<Json> = self
            .compiled
            .program
            .comments
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                c.as_ref().map(|s| Json::arr([Json::num(i as f64), Json::str(s)]))
            })
            .collect();
        let ranges: Vec<Json> = self
            .compiled
            .layer_ranges
            .iter()
            .map(|(li, name, r)| {
                Json::arr([
                    Json::num(*li as f64),
                    Json::str(name),
                    Json::num(r.start as f64),
                    Json::num(r.end as f64),
                ])
            })
            .collect();
        let schedules: Vec<(String, Json)> = self
            .schedules
            .iter()
            .map(|(node, s)| (node.to_string(), schedule_json(s)))
            .collect();
        Json::obj(vec![
            ("format", Json::str(FORMAT_MAGIC)),
            ("version", Json::num(FORMAT_VERSION as f64)),
            ("config_hash", Json::str(&hex(self.config_hash()))),
            ("config", config_json(&self.cfg)),
            ("model", Json::parse(&parser::dump_model(&self.graph)).expect("dump_model emits valid json")),
            ("meta", meta_json(&self.meta)),
            ("schedules", Json::Obj(schedules.into_iter().collect())),
            (
                "output_node",
                self.output_node.map(|n| Json::num(n as f64)).unwrap_or(Json::Null),
            ),
            ("code_len", Json::num(self.compiled.code_len as f64)),
            ("layer_ranges", Json::Arr(ranges)),
            (
                "program",
                Json::obj(vec![
                    ("checksum", Json::str(&hex(words_checksum(&words)))),
                    ("words", Json::arr(words.iter().map(|w| Json::num(*w as f64)))),
                    ("comments", Json::Arr(comments)),
                ]),
            ),
            ("plan", plan_json(&self.compiled.plan)),
        ])
    }

    /// Deserialize without config validation (inspection paths). Use
    /// [`Artifact::validate_config`] or [`Artifact::load`] before
    /// running the result on a machine.
    pub fn from_json(root: &Json) -> Result<Artifact, ArtifactError> {
        if root.get("format").as_str() != Some(FORMAT_MAGIC) {
            return Err(ArtifactError::NotAnArtifact);
        }
        let version = need_u64(root, "version")?;
        if version != FORMAT_VERSION {
            return Err(ArtifactError::FormatVersion { found: version, expected: FORMAT_VERSION });
        }
        let cfg = config_from(root.get("config"))?;
        // The recorded hash must match the recorded config: a mismatch
        // means the file was hand-edited or truncated mid-field.
        let recorded = root
            .get("config_hash")
            .as_str()
            .and_then(unhex)
            .ok_or_else(|| corrupt("config_hash missing or not hex"))?;
        if recorded != config_hash(&cfg) {
            return Err(corrupt("config_hash does not match the embedded config"));
        }
        let graph = parser::parse_model(&root.get("model").dump())
            .map_err(|e| corrupt(&format!("embedded model: {e}")))?;

        let pj = root.get("program");
        let words: Vec<u32> = pj
            .get("words")
            .as_arr()
            .ok_or_else(|| corrupt("program.words missing"))?
            .iter()
            .map(|w| {
                w.as_i64()
                    .and_then(|v| u32::try_from(v).ok())
                    .ok_or_else(|| corrupt("program word out of u32 range"))
            })
            .collect::<Result<_, _>>()?;
        let recorded_sum = pj
            .get("checksum")
            .as_str()
            .and_then(unhex)
            .ok_or_else(|| corrupt("program.checksum missing or not hex"))?;
        if recorded_sum != words_checksum(&words) {
            return Err(corrupt("program checksum mismatch (payload corrupted)"));
        }
        let mut program = Program::new();
        for (i, w) in words.iter().enumerate() {
            let instr = decode(*w).map_err(|e| corrupt(&format!("instruction {i}: {e}")))?;
            // Decode must be the exact inverse of the stored word —
            // anything else means the word was damaged in a way that
            // still decodes (flipped don't-care bits).
            if encode(&instr) != *w {
                return Err(corrupt(&format!("instruction {i} re-encodes differently")));
            }
            program.push(instr);
        }
        for c in pj.get("comments").as_arr().unwrap_or(&[]) {
            let i = c.idx(0).as_usize().ok_or_else(|| corrupt("comment index"))?;
            let s = c.idx(1).as_str().ok_or_else(|| corrupt("comment text"))?;
            if i >= program.comments.len() {
                return Err(corrupt("comment index beyond program length"));
            }
            program.comments[i] = Some(s.to_string());
        }

        let plan = plan_from(root.get("plan"))?;
        if plan.mem_words < plan.program_addr + 2 * program.len() {
            return Err(corrupt("plan.mem_words too small for the program image"));
        }
        validate_plan_bounds(&plan)?;
        let code_len = need(root, "code_len")?;
        let mut layer_ranges = Vec::new();
        for r in root
            .get("layer_ranges")
            .as_arr()
            .ok_or_else(|| corrupt("layer_ranges missing"))?
        {
            let li = r.idx(0).as_usize().ok_or_else(|| corrupt("layer_ranges idx"))?;
            let name = r.idx(1).as_str().ok_or_else(|| corrupt("layer_ranges name"))?;
            let s = r.idx(2).as_usize().ok_or_else(|| corrupt("layer_ranges start"))?;
            let e = r.idx(3).as_usize().ok_or_else(|| corrupt("layer_ranges end"))?;
            layer_ranges.push((li, name.to_string(), s..e));
        }
        let mut schedules = ScheduleMap::new();
        if let Some(map) = root.get("schedules").as_obj() {
            for (k, v) in map {
                let node: usize =
                    k.parse().map_err(|_| corrupt("schedule key is not a node id"))?;
                schedules.insert(node, schedule_from(v)?);
            }
        }
        let output_node = match root.get("output_node") {
            Json::Null => None,
            v => Some(v.as_usize().ok_or_else(|| corrupt("output_node"))?),
        };
        let meta = meta_from(root.get("meta"))?;
        Ok(Artifact {
            cfg,
            graph,
            compiled: CompiledModel { program, plan, layer_ranges, code_len },
            schedules,
            output_node,
            meta,
        })
    }

    /// Hard-error unless the artifact was compiled for `host`.
    pub fn validate_config(&self, host: &SnowflakeConfig) -> Result<(), ArtifactError> {
        if config_hash(&self.cfg) != config_hash(host) {
            return Err(ArtifactError::ConfigMismatch {
                artifact: hex(config_hash(&self.cfg)),
                host: hex(config_hash(host)),
            });
        }
        Ok(())
    }

    /// Write the artifact to `path` (pretty JSON).
    pub fn save(&self, path: &str) -> Result<(), ArtifactError> {
        self.save_format(path, ArtifactFormat::Json)
    }

    /// Write the artifact to `path` in the given encoding.
    pub fn save_format(&self, path: &str, fmt: ArtifactFormat) -> Result<(), ArtifactError> {
        let bytes = match fmt {
            ArtifactFormat::Json => (self.to_json().pretty() + "\n").into_bytes(),
            ArtifactFormat::Bin => self.to_bin(),
        };
        std::fs::write(path, bytes).map_err(|e| ArtifactError::Io(format!("{path}: {e}")))
    }

    /// Read an artifact from `path` and validate it against the host
    /// configuration. Version, config-fingerprint or integrity failures
    /// are typed errors, never silent. Accepts both encodings — the
    /// payload is sniffed, never the extension.
    pub fn load(path: &str, host: &SnowflakeConfig) -> Result<Artifact, ArtifactError> {
        let a = Self::load_unchecked(path)?;
        a.validate_config(host)?;
        Ok(a)
    }

    /// Read an artifact without binding it to a host config (inspection
    /// / cross-config tooling).
    pub fn load_unchecked(path: &str) -> Result<Artifact, ArtifactError> {
        let bytes =
            std::fs::read(path).map_err(|e| ArtifactError::Io(format!("{path}: {e}")))?;
        Self::from_bytes(&bytes)
    }

    /// Decode an artifact from raw bytes, sniffing the encoding:
    /// leading whitespace is skipped, `{` selects the JSON codec, the
    /// 8-byte [`BIN_MAGIC`] selects the binary envelope, anything else
    /// is [`ArtifactError::NotAnArtifact`]. There is no fallback — a
    /// binary payload that fails to decode is never retried as JSON.
    pub fn from_bytes(bytes: &[u8]) -> Result<Artifact, ArtifactError> {
        let mut i = 0;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        let body = &bytes[i..];
        match body.first() {
            None => Err(corrupt("empty file")),
            Some(b'{') => {
                let text = std::str::from_utf8(body)
                    .map_err(|e| ArtifactError::Parse(format!("not utf-8: {e}")))?;
                let root =
                    Json::parse(text).map_err(|e| ArtifactError::Parse(e.to_string()))?;
                Self::from_json(&root)
            }
            Some(_) if body.len() >= BIN_MAGIC.len() && body[..BIN_MAGIC.len()] == BIN_MAGIC => {
                Self::from_bin(body)
            }
            Some(_) => Err(ArtifactError::NotAnArtifact),
        }
    }

    /// Serialize to the binary envelope.
    ///
    /// Layout (all integers little-endian u64 unless noted):
    ///
    /// ```text
    /// [ 0.. 8)  magic  "SNFLKART"
    /// [ 8..16)  FORMAT_VERSION
    /// [16..24)  config fingerprint (config_hash of the embedded config)
    /// [24..32)  section count (== SECTION_TAGS.len())
    /// then per section, a 24-byte table entry:
    ///             tag · payload length · FNV-1a checksum of the payload
    /// then the payloads, concatenated in table order, nothing after.
    /// ```
    ///
    /// Sections (tags ascending, each exactly once): CONFIG, MODEL,
    /// META, PROGRAM, COMMENTS, PLAN, SCHEDULES, EXTRAS. Each payload
    /// is [`lz`]-compressed; checksums and table lengths cover the
    /// compressed bytes, so tampering is caught before any
    /// decompression runs. Under the compression, the PROGRAM payload
    /// is raw — stored words-checksum, word count, then the encoded
    /// u32 instruction words — so the dominant section starts at
    /// 4 bytes/instruction instead of a decimal rendering and the
    /// repetitive per-tile emission collapses further under LZ. Every
    /// other payload is the corresponding `to_json` subtree under the
    /// `bvalue` codec (string-table + varint binary JSON).
    pub fn to_bin(&self) -> Vec<u8> {
        let root = self.to_json();
        let words = program_words(&self.compiled.program);
        let mut program = Vec::with_capacity(16 + words.len() * 4);
        program.extend_from_slice(&words_checksum(&words).to_le_bytes());
        program.extend_from_slice(&(words.len() as u64).to_le_bytes());
        for w in &words {
            program.extend_from_slice(&w.to_le_bytes());
        }
        let extras = Json::obj(vec![
            ("code_len", root.get("code_len").clone()),
            ("layer_ranges", root.get("layer_ranges").clone()),
            ("output_node", root.get("output_node").clone()),
        ]);
        let payloads: Vec<(u64, Vec<u8>)> = vec![
            (SEC_CONFIG, bvalue::encode(root.get("config"))),
            (SEC_MODEL, bvalue::encode(root.get("model"))),
            (SEC_META, bvalue::encode(root.get("meta"))),
            (SEC_PROGRAM, program),
            (SEC_COMMENTS, bvalue::encode(root.get("program").get("comments"))),
            (SEC_PLAN, bvalue::encode(root.get("plan"))),
            (SEC_SCHEDULES, bvalue::encode(root.get("schedules"))),
            (SEC_EXTRAS, bvalue::encode(&extras)),
        ]
        .into_iter()
        .map(|(tag, p)| (tag, lz::compress(&p)))
        .collect();
        let total: usize = payloads.iter().map(|(_, p)| p.len()).sum();
        let mut out = Vec::with_capacity(32 + payloads.len() * 24 + total);
        out.extend_from_slice(&BIN_MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.config_hash().to_le_bytes());
        out.extend_from_slice(&(payloads.len() as u64).to_le_bytes());
        for (tag, p) in &payloads {
            out.extend_from_slice(&tag.to_le_bytes());
            out.extend_from_slice(&(p.len() as u64).to_le_bytes());
            out.extend_from_slice(&fnv1a(p).to_le_bytes());
        }
        for (_, p) in &payloads {
            out.extend_from_slice(p);
        }
        out
    }

    /// Decode the binary envelope. The header is validated first
    /// (magic, version — so a v1/v2 envelope is a typed
    /// [`ArtifactError::FormatVersion`] before any payload is touched),
    /// then the section table (tags ascending and complete, lengths
    /// summing to exactly the remaining bytes, per-section checksums),
    /// and finally the payloads are decompressed ([`lz`]), decoded and
    /// re-assembled into the JSON tree so [`Artifact::from_json`]
    /// reruns every semantic check
    /// the JSON path has: config-hash equality, program words checksum,
    /// per-word decode/re-encode, plan bounds. Binary-loaded artifacts
    /// are bit-identical to JSON-loaded ones by construction.
    pub fn from_bin(bytes: &[u8]) -> Result<Artifact, ArtifactError> {
        let header = |at: usize| -> Result<u64, ArtifactError> {
            let end = at + 8;
            if end > bytes.len() {
                return Err(corrupt("truncated envelope header"));
            }
            Ok(u64::from_le_bytes(bytes[at..end].try_into().unwrap()))
        };
        if bytes.len() < 8 || bytes[..8] != BIN_MAGIC {
            return Err(ArtifactError::NotAnArtifact);
        }
        let version = header(8)?;
        if version != FORMAT_VERSION {
            return Err(ArtifactError::FormatVersion { found: version, expected: FORMAT_VERSION });
        }
        let cfg_hash = header(16)?;
        let nsec = header(24)?;
        if nsec != SECTION_TAGS.len() as u64 {
            return Err(corrupt(&format!(
                "envelope has {nsec} sections, expected {}",
                SECTION_TAGS.len()
            )));
        }
        let table_end = 32 + SECTION_TAGS.len() * 24;
        if bytes.len() < table_end {
            return Err(corrupt("truncated section table"));
        }
        let mut sections: Vec<(u64, usize, u64)> = Vec::with_capacity(SECTION_TAGS.len());
        for (i, &want) in SECTION_TAGS.iter().enumerate() {
            let at = 32 + i * 24;
            let tag = header(at)?;
            if tag != want {
                return Err(corrupt(&format!(
                    "section table entry {i} has tag {tag}, expected {want}"
                )));
            }
            let len = header(at + 8)?;
            let len = usize::try_from(len)
                .ok()
                .filter(|l| *l <= bytes.len())
                .ok_or_else(|| corrupt("section length exceeds file size"))?;
            sections.push((tag, len, header(at + 16)?));
        }
        let total: usize = sections.iter().map(|&(_, l, _)| l).sum();
        if table_end + total != bytes.len() {
            return Err(corrupt(&format!(
                "payload bytes {} do not match section table total {total}",
                bytes.len() - table_end
            )));
        }
        let mut at = table_end;
        let mut payload: std::collections::HashMap<u64, Vec<u8>> = std::collections::HashMap::new();
        for &(tag, len, sum) in &sections {
            let p = &bytes[at..at + len];
            at += len;
            if fnv1a(p) != sum {
                return Err(corrupt(&format!("section {tag} checksum mismatch")));
            }
            payload.insert(tag, lz::decompress(p, section_name(tag))?);
        }

        // PROGRAM is raw (under the LZ layer): stored checksum ·
        // count · u32 words.
        let praw = &payload[&SEC_PROGRAM];
        if praw.len() < 16 {
            return Err(corrupt("program section truncated"));
        }
        let stored_sum = u64::from_le_bytes(praw[..8].try_into().unwrap());
        let count = u64::from_le_bytes(praw[8..16].try_into().unwrap());
        let count = usize::try_from(count)
            .ok()
            .filter(|c| 16 + c * 4 == praw.len())
            .ok_or_else(|| corrupt("program word count does not match section length"))?;
        let words_json: Vec<Json> = (0..count)
            .map(|i| {
                let b = 16 + i * 4;
                Json::num(u32::from_le_bytes(praw[b..b + 4].try_into().unwrap()) as f64)
            })
            .collect();

        let decode_sec = |tag: u64, what: &str| bvalue::decode(&payload[&tag], what);
        let program = Json::obj(vec![
            ("checksum", Json::str(&hex(stored_sum))),
            ("words", Json::Arr(words_json)),
            ("comments", decode_sec(SEC_COMMENTS, "comments")?),
        ]);
        let extras = decode_sec(SEC_EXTRAS, "extras")?;
        let root = Json::obj(vec![
            ("format", Json::str(FORMAT_MAGIC)),
            ("version", Json::num(version as f64)),
            ("config_hash", Json::str(&hex(cfg_hash))),
            ("config", decode_sec(SEC_CONFIG, "config")?),
            ("model", decode_sec(SEC_MODEL, "model")?),
            ("meta", decode_sec(SEC_META, "meta")?),
            ("schedules", decode_sec(SEC_SCHEDULES, "schedules")?),
            ("output_node", extras.get("output_node").clone()),
            ("code_len", extras.get("code_len").clone()),
            ("layer_ranges", extras.get("layer_ranges").clone()),
            ("program", program),
            ("plan", decode_sec(SEC_PLAN, "plan")?),
        ]);
        Self::from_json(&root)
    }
}

// Envelope section tags, ascending; the table must list each exactly
// once in this order.
const SEC_CONFIG: u64 = 1;
const SEC_MODEL: u64 = 2;
const SEC_META: u64 = 3;
const SEC_PROGRAM: u64 = 4;
const SEC_COMMENTS: u64 = 5;
const SEC_PLAN: u64 = 6;
const SEC_SCHEDULES: u64 = 7;
const SEC_EXTRAS: u64 = 8;
const SECTION_TAGS: [u64; 8] = [
    SEC_CONFIG,
    SEC_MODEL,
    SEC_META,
    SEC_PROGRAM,
    SEC_COMMENTS,
    SEC_PLAN,
    SEC_SCHEDULES,
    SEC_EXTRAS,
];

/// Human name for a section tag (error messages).
fn section_name(tag: u64) -> &'static str {
    match tag {
        SEC_CONFIG => "config",
        SEC_MODEL => "model",
        SEC_META => "meta",
        SEC_PROGRAM => "program",
        SEC_COMMENTS => "comments",
        SEC_PLAN => "plan",
        SEC_SCHEDULES => "schedules",
        SEC_EXTRAS => "extras",
        _ => "unknown",
    }
}

/// Compact binary rendering of a `Json` tree: a deduplicating string
/// table (object keys amortize to 1–2 varint bytes) followed by one
/// tagged value. Lossless — integral numbers ride a zigzag varint,
/// everything else keeps its exact f64 bits — so decode∘encode is the
/// identity on the `util/json.rs` value model, which is what makes
/// binary envelopes bit-identical to JSON ones through `from_json`.
/// Decoding is hardened: every count is bounded by the bytes that
/// remain, recursion is depth-limited, and any violation is a typed
/// `Corrupt` — never a panic or an allocation bomb.
mod bvalue {
    use super::{corrupt, ArtifactError};
    use crate::util::json::Json;
    use std::collections::{BTreeMap, HashMap};

    const T_NULL: u8 = 0x00;
    const T_FALSE: u8 = 0x01;
    const T_TRUE: u8 = 0x02;
    const T_INT: u8 = 0x03;
    const T_F64: u8 = 0x04;
    const T_STR: u8 = 0x05;
    const T_ARR: u8 = 0x06;
    const T_OBJ: u8 = 0x07;

    /// JSON numbers are f64; 2^53 bounds the exactly-representable
    /// integers, so only that range takes the varint path.
    const MAX_EXACT: f64 = 9_007_199_254_740_992.0;

    const MAX_DEPTH: usize = 64;

    pub fn encode(v: &Json) -> Vec<u8> {
        let mut strings: Vec<&str> = Vec::new();
        let mut index: HashMap<&str, u64> = HashMap::new();
        collect(v, &mut strings, &mut index);
        let mut out = Vec::new();
        wvarint(&mut out, strings.len() as u64);
        for s in &strings {
            wvarint(&mut out, s.len() as u64);
            out.extend_from_slice(s.as_bytes());
        }
        enc_value(v, &index, &mut out);
        out
    }

    fn collect<'a>(v: &'a Json, strings: &mut Vec<&'a str>, index: &mut HashMap<&'a str, u64>) {
        let mut intern = |s: &'a str, strings: &mut Vec<&'a str>, index: &mut HashMap<&'a str, u64>| {
            if !index.contains_key(s) {
                index.insert(s, strings.len() as u64);
                strings.push(s);
            }
        };
        match v {
            Json::Str(s) => intern(s.as_str(), strings, index),
            Json::Arr(a) => {
                for e in a {
                    collect(e, strings, index);
                }
            }
            Json::Obj(m) => {
                for (k, e) in m {
                    intern(k.as_str(), strings, index);
                    collect(e, strings, index);
                }
            }
            _ => {}
        }
    }

    fn enc_value(v: &Json, index: &HashMap<&str, u64>, out: &mut Vec<u8>) {
        match v {
            Json::Null => out.push(T_NULL),
            Json::Bool(false) => out.push(T_FALSE),
            Json::Bool(true) => out.push(T_TRUE),
            Json::Num(n) => {
                let integral = n.fract() == 0.0
                    && n.abs() <= MAX_EXACT
                    && !(*n == 0.0 && n.is_sign_negative());
                if integral {
                    out.push(T_INT);
                    wvarint(out, zigzag(*n as i64));
                } else {
                    out.push(T_F64);
                    out.extend_from_slice(&n.to_bits().to_le_bytes());
                }
            }
            Json::Str(s) => {
                out.push(T_STR);
                wvarint(out, index[s.as_str()]);
            }
            Json::Arr(a) => {
                out.push(T_ARR);
                wvarint(out, a.len() as u64);
                for e in a {
                    enc_value(e, index, out);
                }
            }
            Json::Obj(m) => {
                out.push(T_OBJ);
                wvarint(out, m.len() as u64);
                for (k, e) in m {
                    wvarint(out, index[k.as_str()]);
                    enc_value(e, index, out);
                }
            }
        }
    }

    pub fn decode(bytes: &[u8], what: &str) -> Result<Json, ArtifactError> {
        let mut r = Reader { b: bytes, pos: 0, what };
        let n = r.varint()? as usize;
        // Each table entry costs at least one length byte, so a count
        // beyond the remaining bytes is corrupt, not an allocation.
        if n > r.remaining() {
            return Err(r.err("string table count exceeds payload"));
        }
        let mut strings = Vec::with_capacity(n);
        for _ in 0..n {
            let len = r.varint()? as usize;
            let raw = r.take(len)?;
            let s = std::str::from_utf8(raw).map_err(|_| r.err("string table not utf-8"))?;
            strings.push(s.to_string());
        }
        let v = dec_value(&mut r, &strings, 0)?;
        if r.pos != r.b.len() {
            return Err(r.err("trailing bytes after value"));
        }
        Ok(v)
    }

    fn dec_value(r: &mut Reader, strings: &[String], depth: usize) -> Result<Json, ArtifactError> {
        if depth > MAX_DEPTH {
            return Err(r.err("nesting too deep"));
        }
        let tag = r.take(1)?[0];
        match tag {
            T_NULL => Ok(Json::Null),
            T_FALSE => Ok(Json::Bool(false)),
            T_TRUE => Ok(Json::Bool(true)),
            T_INT => Ok(Json::Num(unzigzag(r.varint()?) as f64)),
            T_F64 => {
                let raw = r.take(8)?;
                Ok(Json::Num(f64::from_bits(u64::from_le_bytes(raw.try_into().unwrap()))))
            }
            T_STR => Ok(Json::Str(r.string(strings)?)),
            T_ARR => {
                let n = r.varint()? as usize;
                if n > r.remaining() {
                    return Err(r.err("array count exceeds payload"));
                }
                let mut a = Vec::with_capacity(n);
                for _ in 0..n {
                    a.push(dec_value(r, strings, depth + 1)?);
                }
                Ok(Json::Arr(a))
            }
            T_OBJ => {
                let n = r.varint()? as usize;
                if n > r.remaining() {
                    return Err(r.err("object count exceeds payload"));
                }
                let mut m = BTreeMap::new();
                for _ in 0..n {
                    let k = r.string(strings)?;
                    m.insert(k, dec_value(r, strings, depth + 1)?);
                }
                Ok(Json::Obj(m))
            }
            t => Err(r.err(&format!("unknown value tag {t:#04x}"))),
        }
    }

    struct Reader<'a> {
        b: &'a [u8],
        pos: usize,
        what: &'a str,
    }

    impl<'a> Reader<'a> {
        fn remaining(&self) -> usize {
            self.b.len() - self.pos
        }

        fn err(&self, msg: &str) -> ArtifactError {
            corrupt(&format!("{} section: {msg} (at byte {})", self.what, self.pos))
        }

        fn take(&mut self, n: usize) -> Result<&'a [u8], ArtifactError> {
            if n > self.remaining() {
                return Err(self.err("truncated"));
            }
            let s = &self.b[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }

        fn varint(&mut self) -> Result<u64, ArtifactError> {
            let mut v: u64 = 0;
            for shift in (0..70).step_by(7) {
                let b = self.take(1)?[0];
                // The 10th byte holds only bit 63: anything above 1
                // (including a continuation bit) overflows u64.
                if shift == 63 && b > 1 {
                    return Err(self.err("varint overflows u64"));
                }
                v |= ((b & 0x7f) as u64) << shift;
                if b & 0x80 == 0 {
                    return Ok(v);
                }
            }
            unreachable!("loop returns or errors within 10 bytes")
        }

        fn string(&mut self, strings: &[String]) -> Result<String, ArtifactError> {
            let i = self.varint()? as usize;
            strings
                .get(i)
                .cloned()
                .ok_or_else(|| self.err(&format!("string index {i} out of table")))
        }
    }

    /// Shared with [`super::lz`]'s raw-length prefix.
    pub(super) fn wvarint(out: &mut Vec<u8>, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                out.push(b);
                return;
            }
            out.push(b | 0x80);
        }
    }

    /// Standalone varint read for callers without a [`Reader`] (the
    /// [`super::lz`] raw-length prefix). `None` = truncated/overflow.
    pub(super) fn rvarint(b: &[u8], pos: &mut usize) -> Option<u64> {
        let mut v: u64 = 0;
        for shift in (0..70).step_by(7) {
            let byte = *b.get(*pos)?;
            *pos += 1;
            if shift == 63 && byte > 1 {
                return None;
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Some(v);
            }
        }
        None
    }

    fn zigzag(v: i64) -> u64 {
        ((v << 1) ^ (v >> 63)) as u64
    }

    fn unzigzag(z: u64) -> i64 {
        ((z >> 1) as i64) ^ -((z & 1) as i64)
    }
}

/// Byte-oriented LZ77 — the envelope's per-section compressor. The
/// instruction stream (and the plan around it) is block-repetitive:
/// per-tile emission repeats the same few-word shapes with only
/// addresses changing, which a backreference coder collapses far below
/// the 4-bytes-per-word floor of a raw dump.
///
/// Stream format: a uvarint raw (decompressed) length, then tokens —
/// a control byte `< 0x80` copies `ctrl + 1` following literal bytes;
/// a control byte `>= 0x80` copies `ctrl - 0x80 + 4` bytes starting
/// `offset` bytes back in the output, `offset` the following
/// little-endian u16 (1-based; overlapping copies allowed, so a
/// one-byte period still encodes).
///
/// The encoder is a greedy single-probe hash matcher and fully
/// deterministic — same input, same bytes — which keeps
/// [`Artifact::to_bin`] canonical. Decoding is hardened like
/// [`bvalue`]: every length and offset is bounds-checked, output can
/// never exceed the declared raw length, and every violation is a
/// typed `Corrupt` — never a panic or an allocation bomb.
mod lz {
    use super::{bvalue, corrupt, ArtifactError};

    const MIN_MATCH: usize = 4;
    /// Control byte carries `len - MIN_MATCH` in its low 7 bits.
    const MAX_MATCH: usize = MIN_MATCH + 0x7f;
    const MAX_OFFSET: usize = u16::MAX as usize;
    const HASH_BITS: u32 = 16;

    fn hash4(b: &[u8]) -> usize {
        let v = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
    }

    pub fn compress(src: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(src.len() / 2 + 16);
        bvalue::wvarint(&mut out, src.len() as u64);
        let mut table = vec![usize::MAX; 1 << HASH_BITS];
        let mut i = 0usize;
        let mut lit = 0usize; // start of the pending literal run
        while i + MIN_MATCH <= src.len() {
            let h = hash4(&src[i..]);
            let cand = table[h];
            table[h] = i;
            if cand != usize::MAX
                && i - cand <= MAX_OFFSET
                && src[cand..cand + MIN_MATCH] == src[i..i + MIN_MATCH]
            {
                let mut len = MIN_MATCH;
                while len < MAX_MATCH && i + len < src.len() && src[cand + len] == src[i + len] {
                    len += 1;
                }
                flush_literals(&mut out, &src[lit..i]);
                out.push(0x80 + (len - MIN_MATCH) as u8);
                out.extend_from_slice(&((i - cand) as u16).to_le_bytes());
                i += len;
                lit = i;
            } else {
                i += 1;
            }
        }
        flush_literals(&mut out, &src[lit..]);
        out
    }

    /// Emit a literal run as chunks of at most 128 bytes (control byte
    /// `n - 1` in `0..=0x7f`).
    fn flush_literals(out: &mut Vec<u8>, mut lits: &[u8]) {
        while !lits.is_empty() {
            let n = lits.len().min(0x80);
            out.push((n - 1) as u8);
            out.extend_from_slice(&lits[..n]);
            lits = &lits[n..];
        }
    }

    pub fn decompress(src: &[u8], what: &str) -> Result<Vec<u8>, ArtifactError> {
        let oops = |msg: &str| corrupt(&format!("{what} section: {msg}"));
        let mut pos = 0usize;
        let raw = bvalue::rvarint(src, &mut pos)
            .ok_or_else(|| oops("truncated raw-length varint"))?;
        // A 3-byte match token expands to at most MAX_MATCH bytes, so
        // a declared raw length beyond that ratio cannot be real — and
        // cannot be turned into an allocation bomb.
        let raw = usize::try_from(raw)
            .ok()
            .filter(|r| *r / MAX_MATCH <= src.len())
            .ok_or_else(|| oops("declared raw length impossible for payload size"))?;
        let mut out = Vec::with_capacity(raw.min(1 << 20));
        while pos < src.len() {
            let ctrl = src[pos];
            pos += 1;
            if ctrl < 0x80 {
                let n = ctrl as usize + 1;
                if pos + n > src.len() {
                    return Err(oops("literal run past end of payload"));
                }
                if out.len() + n > raw {
                    return Err(oops("output exceeds declared raw length"));
                }
                out.extend_from_slice(&src[pos..pos + n]);
                pos += n;
            } else {
                let len = (ctrl - 0x80) as usize + MIN_MATCH;
                if pos + 2 > src.len() {
                    return Err(oops("match token truncated"));
                }
                let off = u16::from_le_bytes([src[pos], src[pos + 1]]) as usize;
                pos += 2;
                if off == 0 || off > out.len() {
                    return Err(oops("match offset outside decoded output"));
                }
                if out.len() + len > raw {
                    return Err(oops("output exceeds declared raw length"));
                }
                // Byte-wise so overlapping (short-period) copies work.
                for _ in 0..len {
                    let b = out[out.len() - off];
                    out.push(b);
                }
            }
        }
        if out.len() != raw {
            return Err(oops("decoded length does not match declaration"));
        }
        Ok(out)
    }
}

/// Every memory region the plan names must fall inside `mem_words`:
/// a corrupted plan that passed the JSON grammar would otherwise panic
/// (slice out of bounds) or silently overwrite neighbouring regions at
/// deploy time — the failures this module promises are typed errors.
/// (u128 arithmetic: JSON numbers cap at 2^53, so products cannot be
/// made to wrap past the check.)
fn validate_plan_bounds(plan: &Plan) -> Result<(), ArtifactError> {
    let mem = plan.mem_words as u128;
    let check = |what: &str, base: usize, words: u128| -> Result<(), ArtifactError> {
        if base as u128 + words > mem {
            return Err(corrupt(&format!(
                "{what} region [{base}, +{words}) falls outside mem_words {}",
                plan.mem_words
            )));
        }
        Ok(())
    };
    let canvas_words = |c: &Canvas| {
        c.w_canvas() as u128 * c.h_canvas() as u128 * c.c_pad as u128
    };
    check("input canvas", plan.input_canvas.base, canvas_words(&plan.input_canvas))?;
    for (n, c) in &plan.canvases {
        check(&format!("canvas {n}"), c.base, canvas_words(c))?;
    }
    check("zero", plan.zero_addr, 64)?;
    for (i, lp) in plan.layers.iter().enumerate() {
        check(&format!("layer {i} weights"), lp.weights_addr, lp.weights_words as u128)?;
        check(&format!("layer {i} bias"), lp.bias_addr, lp.bias_words as u128)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Config fingerprint
// ---------------------------------------------------------------------

/// FNV-1a over a canonical field-by-field rendering of the config. Any
/// parameter change — and any schema change to `SnowflakeConfig`
/// itself, via the field list below — changes the fingerprint, which is
/// exactly the invalidation we want for compiled artifacts.
pub fn config_hash(c: &SnowflakeConfig) -> u64 {
    let canon = format!(
        "clock_mhz={};n_cus={};vmacs_per_cu={};macs_per_vmac={};word_bytes={};\
         mbuf_bank_bytes={};mbuf_banks={};wbuf_bytes={};bbuf_bytes={};\
         icache_banks={};icache_bank_instrs={};n_load_units={};axi_bytes_per_cycle={};\
         dma_setup_cycles={};link_bandwidth_gbs={};vector_queue_depth={};branch_delay_slots={};\
         scalar_exec_cycles={};gather_cycles={}",
        c.clock_mhz,
        c.n_cus,
        c.vmacs_per_cu,
        c.macs_per_vmac,
        c.word_bytes,
        c.mbuf_bank_bytes,
        c.mbuf_banks,
        c.wbuf_bytes,
        c.bbuf_bytes,
        c.icache_banks,
        c.icache_bank_instrs,
        c.n_load_units,
        c.axi_bytes_per_cycle,
        c.dma_setup_cycles,
        c.link_bandwidth_gbs,
        c.vector_queue_depth,
        c.branch_delay_slots,
        c.scalar_exec_cycles,
        c.gather_cycles
    );
    fnv1a(canon.as_bytes())
}

pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn words_checksum(words: &[u32]) -> u64 {
    let mut bytes = Vec::with_capacity(words.len() * 4);
    for w in words {
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    fnv1a(&bytes)
}

fn program_words(p: &Program) -> Vec<u32> {
    p.instrs.iter().map(encode).collect()
}

pub(crate) fn hex(v: u64) -> String {
    format!("{v:016x}")
}

pub(crate) fn unhex(s: &str) -> Option<u64> {
    (s.len() == 16).then(|| u64::from_str_radix(s, 16).ok()).flatten()
}

fn corrupt(msg: &str) -> ArtifactError {
    ArtifactError::Corrupt(msg.to_string())
}

fn need(j: &Json, key: &str) -> Result<usize, ArtifactError> {
    j.get(key).as_usize().ok_or_else(|| corrupt(&format!("missing/invalid field '{key}'")))
}

fn need_u64(j: &Json, key: &str) -> Result<u64, ArtifactError> {
    j.get(key)
        .as_i64()
        .and_then(|v| u64::try_from(v).ok())
        .ok_or_else(|| corrupt(&format!("missing/invalid field '{key}'")))
}

fn need_bool(j: &Json, key: &str) -> Result<bool, ArtifactError> {
    j.get(key).as_bool().ok_or_else(|| corrupt(&format!("missing/invalid field '{key}'")))
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, ArtifactError> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => Ok(Some(v.as_usize().ok_or_else(|| corrupt(&format!("field '{key}'")))?)),
    }
}

fn ju(n: usize) -> Json {
    Json::Num(n as f64)
}

fn ju64(n: u64) -> Json {
    Json::Num(n as f64)
}

fn jopt(n: Option<usize>) -> Json {
    n.map(ju).unwrap_or(Json::Null)
}

// ---------------------------------------------------------------------
// Config / meta / schedule codecs
// ---------------------------------------------------------------------

pub(crate) fn config_json(c: &SnowflakeConfig) -> Json {
    Json::obj(vec![
        ("clock_mhz", Json::Num(c.clock_mhz)),
        ("n_cus", ju(c.n_cus)),
        ("vmacs_per_cu", ju(c.vmacs_per_cu)),
        ("macs_per_vmac", ju(c.macs_per_vmac)),
        ("word_bytes", ju(c.word_bytes)),
        ("mbuf_bank_bytes", ju(c.mbuf_bank_bytes)),
        ("mbuf_banks", ju(c.mbuf_banks)),
        ("wbuf_bytes", ju(c.wbuf_bytes)),
        ("bbuf_bytes", ju(c.bbuf_bytes)),
        ("icache_banks", ju(c.icache_banks)),
        ("icache_bank_instrs", ju(c.icache_bank_instrs)),
        ("n_load_units", ju(c.n_load_units)),
        ("axi_bytes_per_cycle", Json::Num(c.axi_bytes_per_cycle)),
        ("dma_setup_cycles", ju64(c.dma_setup_cycles)),
        ("link_bandwidth_gbs", Json::Num(c.link_bandwidth_gbs)),
        ("vector_queue_depth", ju(c.vector_queue_depth)),
        ("branch_delay_slots", ju(c.branch_delay_slots)),
        ("scalar_exec_cycles", ju64(c.scalar_exec_cycles)),
        ("gather_cycles", ju64(c.gather_cycles)),
    ])
}

pub(crate) fn config_from(j: &Json) -> Result<SnowflakeConfig, ArtifactError> {
    let f = |key: &str| -> Result<f64, ArtifactError> {
        j.get(key).as_f64().ok_or_else(|| corrupt(&format!("config.{key}")))
    };
    Ok(SnowflakeConfig {
        clock_mhz: f("clock_mhz")?,
        n_cus: need(j, "n_cus")?,
        vmacs_per_cu: need(j, "vmacs_per_cu")?,
        macs_per_vmac: need(j, "macs_per_vmac")?,
        word_bytes: need(j, "word_bytes")?,
        mbuf_bank_bytes: need(j, "mbuf_bank_bytes")?,
        mbuf_banks: need(j, "mbuf_banks")?,
        wbuf_bytes: need(j, "wbuf_bytes")?,
        bbuf_bytes: need(j, "bbuf_bytes")?,
        icache_banks: need(j, "icache_banks")?,
        icache_bank_instrs: need(j, "icache_bank_instrs")?,
        n_load_units: need(j, "n_load_units")?,
        axi_bytes_per_cycle: f("axi_bytes_per_cycle")?,
        dma_setup_cycles: need_u64(j, "dma_setup_cycles")?,
        link_bandwidth_gbs: f("link_bandwidth_gbs")?,
        vector_queue_depth: need(j, "vector_queue_depth")?,
        branch_delay_slots: need(j, "branch_delay_slots")?,
        scalar_exec_cycles: need_u64(j, "scalar_exec_cycles")?,
        gather_cycles: need_u64(j, "gather_cycles")?,
    })
}

fn meta_json(m: &ArtifactMeta) -> Json {
    Json::obj(vec![
        ("tune", Json::str(&m.tune)),
        ("balance", Json::str(&m.balance)),
        ("smart_delay_slots", Json::Bool(m.smart_delay_slots)),
        ("reuse_regions", Json::Bool(m.reuse_regions)),
        ("skip_fc", Json::Bool(m.skip_fc)),
    ])
}

fn meta_from(j: &Json) -> Result<ArtifactMeta, ArtifactError> {
    Ok(ArtifactMeta {
        tune: j.get("tune").as_str().unwrap_or("?").to_string(),
        balance: j.get("balance").as_str().unwrap_or("?").to_string(),
        smart_delay_slots: need_bool(j, "smart_delay_slots")?,
        reuse_regions: need_bool(j, "reuse_regions")?,
        skip_fc: need_bool(j, "skip_fc")?,
    })
}

fn policy_str(p: BalancePolicy) -> String {
    match p {
        BalancePolicy::Greedy { split } => format!("greedy{split}"),
        BalancePolicy::TwoUnits => "two-units".to_string(),
        BalancePolicy::OneUnit => "one-unit".to_string(),
    }
}

fn policy_json(p: BalancePolicy) -> Json {
    match p {
        BalancePolicy::Greedy { split } => {
            Json::obj(vec![("kind", Json::str("greedy")), ("split", ju(split))])
        }
        BalancePolicy::TwoUnits => Json::obj(vec![("kind", Json::str("two-units"))]),
        BalancePolicy::OneUnit => Json::obj(vec![("kind", Json::str("one-unit"))]),
    }
}

fn policy_from(j: &Json) -> Result<BalancePolicy, ArtifactError> {
    match j.get("kind").as_str() {
        Some("greedy") => Ok(BalancePolicy::Greedy { split: need(j, "split")? }),
        Some("two-units") => Ok(BalancePolicy::TwoUnits),
        Some("one-unit") => Ok(BalancePolicy::OneUnit),
        _ => Err(corrupt("unknown balance policy")),
    }
}

fn order_str(o: LoopOrder) -> &'static str {
    match o {
        LoopOrder::Mloop => "mloop",
        LoopOrder::Kloop => "kloop",
        LoopOrder::MloopRot => "mloop-rot",
    }
}

fn order_from(j: &Json) -> Result<LoopOrder, ArtifactError> {
    match j.as_str() {
        Some("mloop") => Ok(LoopOrder::Mloop),
        Some("kloop") => Ok(LoopOrder::Kloop),
        Some("mloop-rot") => Ok(LoopOrder::MloopRot),
        // Any other order came from a different (future) format or a
        // damaged file — typed rejection, never a silent Kloop.
        _ => Err(corrupt("unknown loop order")),
    }
}

fn schedule_json(s: &Schedule) -> Json {
    Json::obj(vec![
        ("order", Json::str(order_str(s.order))),
        ("rows_per_cu", ju(s.rows_per_cu)),
        ("policy", policy_json(s.policy)),
    ])
}

fn schedule_from(j: &Json) -> Result<Schedule, ArtifactError> {
    Ok(Schedule {
        order: order_from(j.get("order"))?,
        rows_per_cu: need(j, "rows_per_cu")?,
        policy: policy_from(j.get("policy"))?,
    })
}

// ---------------------------------------------------------------------
// Plan codec
// ---------------------------------------------------------------------

fn canvas_json(c: &Canvas) -> Json {
    Json::obj(vec![
        ("base", ju(c.base)),
        ("c", ju(c.c)),
        ("h", ju(c.h)),
        ("w", ju(c.w)),
        ("c_pad", ju(c.c_pad)),
        ("mp", ju(c.mp)),
        ("h_slack", ju(c.h_slack)),
        ("w_slack", ju(c.w_slack)),
    ])
}

fn canvas_from(j: &Json) -> Result<Canvas, ArtifactError> {
    Ok(Canvas {
        base: need(j, "base")?,
        c: need(j, "c")?,
        h: need(j, "h")?,
        w: need(j, "w")?,
        c_pad: need(j, "c_pad")?,
        mp: need(j, "mp")?,
        h_slack: need(j, "h_slack")?,
        w_slack: need(j, "w_slack")?,
    })
}

fn lowered_json(op: &Lowered) -> Json {
    match *op {
        Lowered::Conv { node, src, bypass, in_ch, out_ch, kh, kw, stride, pad, relu } => {
            Json::obj(vec![
                ("kind", Json::str("conv")),
                ("node", ju(node)),
                ("src", jopt(src)),
                ("bypass", jopt(bypass)),
                ("in_ch", ju(in_ch)),
                ("out_ch", ju(out_ch)),
                ("kh", ju(kh)),
                ("kw", ju(kw)),
                ("stride", ju(stride)),
                ("pad", ju(pad)),
                ("relu", Json::Bool(relu)),
            ])
        }
        Lowered::MaxPool { node, src, kh, kw, stride, pad } => Json::obj(vec![
            ("kind", Json::str("maxpool")),
            ("node", ju(node)),
            ("src", jopt(src)),
            ("kh", ju(kh)),
            ("kw", ju(kw)),
            ("stride", ju(stride)),
            ("pad", ju(pad)),
        ]),
        Lowered::AvgPool { node, src, kh, kw, stride, pad } => Json::obj(vec![
            ("kind", Json::str("avgpool")),
            ("node", ju(node)),
            ("src", jopt(src)),
            ("kh", ju(kh)),
            ("kw", ju(kw)),
            ("stride", ju(stride)),
            ("pad", ju(pad)),
        ]),
        Lowered::Fc { node, src, in_features, out_features, relu } => Json::obj(vec![
            ("kind", Json::str("fc")),
            ("node", ju(node)),
            ("src", jopt(src)),
            ("in_features", ju(in_features)),
            ("out_features", ju(out_features)),
            ("relu", Json::Bool(relu)),
        ]),
    }
}

fn lowered_from(j: &Json) -> Result<Lowered, ArtifactError> {
    match j.get("kind").as_str() {
        Some("conv") => Ok(Lowered::Conv {
            node: need(j, "node")?,
            src: opt_usize(j, "src")?,
            bypass: opt_usize(j, "bypass")?,
            in_ch: need(j, "in_ch")?,
            out_ch: need(j, "out_ch")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
            relu: need_bool(j, "relu")?,
        }),
        Some("maxpool") => Ok(Lowered::MaxPool {
            node: need(j, "node")?,
            src: opt_usize(j, "src")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
        }),
        Some("avgpool") => Ok(Lowered::AvgPool {
            node: need(j, "node")?,
            src: opt_usize(j, "src")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
        }),
        Some("fc") => Ok(Lowered::Fc {
            node: need(j, "node")?,
            src: opt_usize(j, "src")?,
            in_features: need(j, "in_features")?,
            out_features: need(j, "out_features")?,
            relu: need_bool(j, "relu")?,
        }),
        _ => Err(corrupt("unknown lowered-op kind")),
    }
}

fn estimate_json(e: &CostEstimate) -> Json {
    Json::obj(vec![
        ("cycles", ju64(e.cycles)),
        ("dram_bytes", ju64(e.dram_bytes)),
        ("compute_cycles", ju64(e.compute_cycles)),
        ("issue_cycles", ju64(e.issue_cycles)),
        ("dma_cycles", ju64(e.dma_cycles)),
        ("startup_cycles", ju64(e.startup_cycles)),
        ("streams", ju64(e.streams)),
    ])
}

fn estimate_from(j: &Json) -> Result<CostEstimate, ArtifactError> {
    Ok(CostEstimate {
        cycles: need_u64(j, "cycles")?,
        dram_bytes: need_u64(j, "dram_bytes")?,
        compute_cycles: need_u64(j, "compute_cycles")?,
        issue_cycles: need_u64(j, "issue_cycles")?,
        dma_cycles: need_u64(j, "dma_cycles")?,
        startup_cycles: need_u64(j, "startup_cycles")?,
        streams: need_u64(j, "streams")?,
    })
}

fn geom_json(g: &Geom) -> Json {
    Json::obj(vec![
        ("row_read", ju(g.row_read)),
        ("segs", Json::arr(g.segs.iter().map(|s| ju(*s)))),
        ("in_w_slack", ju(g.in_w_slack)),
    ])
}

fn geom_from(j: &Json) -> Result<Geom, ArtifactError> {
    Ok(Geom {
        row_read: need(j, "row_read")?,
        segs: j
            .get("segs")
            .as_arr()
            .ok_or_else(|| corrupt("geom.segs"))?
            .iter()
            .map(|s| s.as_usize().ok_or_else(|| corrupt("geom.segs entry")))
            .collect::<Result<_, _>>()?,
        in_w_slack: need(j, "in_w_slack")?,
    })
}

fn decision_json(d: &OpPlan) -> Json {
    match d {
        OpPlan::Conv(c) => Json::obj(vec![
            ("kind", Json::str("conv")),
            ("c_pad_in", ju(c.c_pad_in)),
            ("c_pad_out", ju(c.c_pad_out)),
            ("kh", ju(c.kh)),
            ("kw", ju(c.kw)),
            ("stride", ju(c.stride)),
            ("pad", ju(c.pad)),
            ("h_out", ju(c.h_out)),
            ("w_out", ju(c.w_out)),
            ("geom", geom_json(&c.geom)),
            ("kernel_words", ju(c.kernel_words)),
            ("k_groups", ju(c.k_groups)),
            ("rows_per_cu", ju(c.rows_per_cu)),
            ("n_tiles", ju(c.n_tiles)),
            ("order", Json::str(order_str(c.order))),
            ("split", ju(c.split)),
            ("policy", policy_json(c.policy)),
            ("max_rows", ju(c.max_rows)),
            ("predicted", estimate_json(&c.predicted)),
            ("dbuf_w", Json::Bool(c.dbuf_w)),
            ("has_bypass", Json::Bool(c.has_bypass)),
            ("relu", Json::Bool(c.relu)),
        ]),
        OpPlan::MaxPool(p) => Json::obj(vec![
            ("kind", Json::str("maxpool")),
            ("c", ju(p.c)),
            ("c_pad", ju(p.c_pad)),
            ("kh", ju(p.kh)),
            ("kw", ju(p.kw)),
            ("stride", ju(p.stride)),
            ("pad", ju(p.pad)),
            ("h_out", ju(p.h_out)),
            ("w_out", ju(p.w_out)),
            ("x_groups", ju(p.x_groups)),
            ("rows_per_cu", ju(p.rows_per_cu)),
            ("n_tiles", ju(p.n_tiles)),
            ("spill", ju(p.spill)),
            ("max_rows", ju(p.max_rows)),
            ("predicted", estimate_json(&p.predicted)),
        ]),
        OpPlan::AvgPool(a) => Json::obj(vec![
            ("kind", Json::str("avgpool")),
            ("c", ju(a.c)),
            ("c_pad", ju(a.c_pad)),
            ("kh", ju(a.kh)),
            ("kw", ju(a.kw)),
            ("stride", ju(a.stride)),
            ("h_out", ju(a.h_out)),
            ("w_out", ju(a.w_out)),
            ("chunks", ju(a.chunks)),
        ]),
        OpPlan::Fc(f) => Json::obj(vec![
            ("kind", Json::str("fc")),
            ("in_features", ju(f.in_features)),
            ("out_features", ju(f.out_features)),
            ("k_groups", ju(f.k_groups)),
            ("chunks", Json::arr(f.chunks.iter().map(|c| ju(*c)))),
            ("relu", Json::Bool(f.relu)),
        ]),
    }
}

fn decision_from(j: &Json) -> Result<OpPlan, ArtifactError> {
    match j.get("kind").as_str() {
        Some("conv") => Ok(OpPlan::Conv(ConvPlan {
            c_pad_in: need(j, "c_pad_in")?,
            c_pad_out: need(j, "c_pad_out")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
            h_out: need(j, "h_out")?,
            w_out: need(j, "w_out")?,
            geom: geom_from(j.get("geom"))?,
            kernel_words: need(j, "kernel_words")?,
            k_groups: need(j, "k_groups")?,
            rows_per_cu: need(j, "rows_per_cu")?,
            n_tiles: need(j, "n_tiles")?,
            order: order_from(j.get("order"))?,
            split: need(j, "split")?,
            policy: policy_from(j.get("policy"))?,
            max_rows: need(j, "max_rows")?,
            predicted: estimate_from(j.get("predicted"))?,
            dbuf_w: need_bool(j, "dbuf_w")?,
            has_bypass: need_bool(j, "has_bypass")?,
            relu: need_bool(j, "relu")?,
        })),
        Some("maxpool") => Ok(OpPlan::MaxPool(PoolPlan {
            c: need(j, "c")?,
            c_pad: need(j, "c_pad")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            pad: need(j, "pad")?,
            h_out: need(j, "h_out")?,
            w_out: need(j, "w_out")?,
            x_groups: need(j, "x_groups")?,
            rows_per_cu: need(j, "rows_per_cu")?,
            n_tiles: need(j, "n_tiles")?,
            spill: need(j, "spill")?,
            max_rows: need(j, "max_rows")?,
            predicted: estimate_from(j.get("predicted"))?,
        })),
        Some("avgpool") => Ok(OpPlan::AvgPool(AvgPlan {
            c: need(j, "c")?,
            c_pad: need(j, "c_pad")?,
            kh: need(j, "kh")?,
            kw: need(j, "kw")?,
            stride: need(j, "stride")?,
            h_out: need(j, "h_out")?,
            w_out: need(j, "w_out")?,
            chunks: need(j, "chunks")?,
        })),
        Some("fc") => Ok(OpPlan::Fc(FcPlan {
            in_features: need(j, "in_features")?,
            out_features: need(j, "out_features")?,
            k_groups: need(j, "k_groups")?,
            chunks: j
                .get("chunks")
                .as_arr()
                .ok_or_else(|| corrupt("fc.chunks"))?
                .iter()
                .map(|c| c.as_usize().ok_or_else(|| corrupt("fc.chunks entry")))
                .collect::<Result<_, _>>()?,
            relu: need_bool(j, "relu")?,
        })),
        _ => Err(corrupt("unknown decision kind")),
    }
}

fn plan_json(p: &Plan) -> Json {
    let canvases: BTreeMap<String, Json> =
        p.canvases.iter().map(|(n, c)| (n.to_string(), canvas_json(c))).collect();
    let layers: Vec<Json> = p
        .layers
        .iter()
        .map(|lp| {
            Json::obj(vec![
                ("op", lowered_json(&lp.op)),
                ("decision", decision_json(&lp.decision)),
                ("weights_addr", ju(lp.weights_addr)),
                ("weights_words", ju(lp.weights_words)),
                ("bias_addr", ju(lp.bias_addr)),
                ("bias_words", ju(lp.bias_words)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("fmt_frac", ju(p.fmt.frac as usize)),
        ("input_canvas", canvas_json(&p.input_canvas)),
        ("canvases", Json::Obj(canvases)),
        ("layers", Json::Arr(layers)),
        ("zero_addr", ju(p.zero_addr)),
        ("program_addr", ju(p.program_addr)),
        ("mem_words", ju(p.mem_words)),
        ("activation_words", ju(p.activation_words)),
    ])
}

fn plan_from(j: &Json) -> Result<Plan, ArtifactError> {
    let frac = need(j, "fmt_frac")?;
    if frac >= 16 {
        return Err(corrupt("fmt_frac out of range"));
    }
    let mut canvases = BTreeMap::new();
    if let Some(map) = j.get("canvases").as_obj() {
        for (k, v) in map {
            let node: usize = k.parse().map_err(|_| corrupt("canvas key"))?;
            canvases.insert(node, canvas_from(v)?);
        }
    }
    let mut layers = Vec::new();
    for l in j.get("layers").as_arr().ok_or_else(|| corrupt("plan.layers"))? {
        layers.push(LayerPlan {
            op: lowered_from(l.get("op"))?,
            decision: decision_from(l.get("decision"))?,
            weights_addr: need(l, "weights_addr")?,
            weights_words: need(l, "weights_words")?,
            bias_addr: need(l, "bias_addr")?,
            bias_words: need(l, "bias_words")?,
        });
    }
    Ok(Plan {
        fmt: QFormat::new(frac as u32),
        input_canvas: canvas_from(j.get("input_canvas"))?,
        canvases,
        layers,
        zero_addr: need(j, "zero_addr")?,
        program_addr: need(j, "program_addr")?,
        mem_words: need(j, "mem_words")?,
        activation_words: need(j, "activation_words")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::Compiler;
    use crate::model::layer::{LayerKind, Shape};

    fn small_graph() -> Graph {
        let mut g = Graph::new("artifact_small", Shape::new(16, 12, 12));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c1",
        );
        g.push_seq(LayerKind::MaxPool { kh: 2, kw: 2, stride: 2, pad: 0 }, "p1");
        g
    }

    fn build_small() -> Artifact {
        Compiler::new(SnowflakeConfig::default())
            .build(&small_graph())
            .expect("build")
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let a = build_small();
        let back = Artifact::from_json(&a.to_json()).expect("roundtrip");
        assert_eq!(back.compiled.program, a.compiled.program, "program must round-trip exactly");
        assert_eq!(back.compiled.plan, a.compiled.plan, "plan must round-trip exactly");
        assert_eq!(back.compiled.layer_ranges, a.compiled.layer_ranges);
        assert_eq!(back.compiled.code_len, a.compiled.code_len);
        assert_eq!(back.schedules, a.schedules);
        assert_eq!(back.output_node, a.output_node);
        assert_eq!(back.meta, a.meta);
        assert_eq!(back.cfg, a.cfg);
        assert_eq!(back.graph.nodes.len(), a.graph.nodes.len());
        // Re-serialization is stable (byte-identical text).
        assert_eq!(back.to_json().pretty(), a.to_json().pretty());
    }

    #[test]
    fn fingerprint_distinguishes_quantization_formats() {
        // Q8.8 and Q5.11 builds of the same model emit identical
        // program words but deploy differently-quantized weight
        // images; the cache key must tell them apart.
        let g = small_graph();
        let cfg = SnowflakeConfig::default();
        let a8 = Compiler::new(cfg.clone()).build(&g).unwrap();
        let a11 = Compiler::new(cfg)
            .options(CompileOptions { fmt: crate::fixed::Q5_11, ..Default::default() })
            .build(&g)
            .unwrap();
        assert_ne!(a8.fingerprint(), a11.fingerprint());
        // Stable across clones of the same artifact.
        assert_eq!(a8.fingerprint(), a8.clone().fingerprint());
    }

    #[test]
    fn config_hash_is_stable_and_sensitive() {
        let c = SnowflakeConfig::default();
        assert_eq!(config_hash(&c), config_hash(&c.clone()));
        let c2 = SnowflakeConfig { n_cus: 8, ..c.clone() };
        assert_ne!(config_hash(&c), config_hash(&c2));
        let c3 = SnowflakeConfig { dma_setup_cycles: 65, ..c.clone() };
        assert_ne!(config_hash(&c3), config_hash(&SnowflakeConfig::default()));
        // v3: the inter-stage link bandwidth is part of the schema, so a
        // different link speed invalidates compiled artifacts too.
        let c4 = SnowflakeConfig { link_bandwidth_gbs: 2.0, ..c };
        assert_ne!(config_hash(&c4), config_hash(&SnowflakeConfig::default()));
    }

    #[test]
    fn config_mismatch_is_a_hard_typed_error() {
        let a = build_small();
        let other = SnowflakeConfig { mbuf_bank_bytes: 32 * 1024, ..SnowflakeConfig::default() };
        let err = a.validate_config(&other).unwrap_err();
        assert!(matches!(err, ArtifactError::ConfigMismatch { .. }), "{err}");
        assert!(a.validate_config(&SnowflakeConfig::default()).is_ok());
    }

    #[test]
    fn version_mismatch_rejected() {
        let a = build_small();
        let mut j = a.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::num(99));
        }
        let err = Artifact::from_json(&j).unwrap_err();
        assert_eq!(
            err,
            ArtifactError::FormatVersion { found: 99, expected: FORMAT_VERSION }
        );
    }

    #[test]
    fn v1_artifacts_rejected_with_typed_error() {
        // Pre-rotation artifacts (format v1) predate the `mloop-rot`
        // order and its cost model: loading one must be a typed
        // FormatVersion error, not a best-effort parse.
        let a = build_small();
        let mut j = a.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::num(1.0));
        }
        let err = Artifact::from_json(&j).unwrap_err();
        assert_eq!(err, ArtifactError::FormatVersion { found: 1, expected: FORMAT_VERSION });
    }

    #[test]
    fn v2_artifacts_rejected_with_typed_error() {
        // Format-v2 artifacts predate `link_bandwidth_gbs` in the
        // config schema: their config hash was computed without the
        // field, so loading one must be a typed FormatVersion error
        // ("rebuild"), not a baffling config-mismatch hex pair.
        let a = build_small();
        let mut j = a.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::num(2.0));
        }
        let err = Artifact::from_json(&j).unwrap_err();
        assert_eq!(err, ArtifactError::FormatVersion { found: 2, expected: FORMAT_VERSION });
    }

    #[test]
    fn unknown_loop_order_rejected_on_load() {
        assert!(order_from(&Json::str("mloop")).is_ok());
        assert!(order_from(&Json::str("mloop-rot")).is_ok());
        let err = order_from(&Json::str("zloop")).unwrap_err();
        assert!(matches!(err, ArtifactError::Corrupt(_)), "{err}");
        // Round-trip of every order string.
        for o in [LoopOrder::Kloop, LoopOrder::Mloop, LoopOrder::MloopRot] {
            assert_eq!(order_from(&Json::str(order_str(o))).unwrap(), o);
        }
    }

    #[test]
    fn corrupted_program_word_rejected() {
        let a = build_small();
        let mut j = a.to_json();
        // Flip one program word without updating the checksum.
        if let Json::Obj(o) = &mut j {
            let p = o.get_mut("program").unwrap();
            if let Json::Obj(po) = p {
                if let Some(Json::Arr(words)) = po.get_mut("words") {
                    words[3] = Json::num(0x1234_5678u32 as f64);
                }
            }
        }
        let err = Artifact::from_json(&j).unwrap_err();
        assert!(matches!(err, ArtifactError::Corrupt(_)), "{err}");
    }

    #[test]
    fn non_artifact_json_rejected() {
        let err = Artifact::from_json(&Json::parse(r#"{"hello": 1}"#).unwrap()).unwrap_err();
        assert_eq!(err, ArtifactError::NotAnArtifact);
    }

    #[test]
    fn hex_helpers_roundtrip() {
        for v in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(unhex(&hex(v)), Some(v));
        }
        assert_eq!(unhex("xyz"), None);
        assert_eq!(unhex("123"), None); // wrong length
    }

    #[test]
    fn bvalue_roundtrips_every_json_shape() {
        let cases = [
            "null",
            "true",
            "false",
            "0",
            "-1",
            "9007199254740992",
            "-9007199254740992",
            "0.5",
            "-123.25",
            "1e300",
            r#""""#,
            r#""hello world""#,
            r#"[]"#,
            r#"[1, [2, [3, "x"]], null]"#,
            r#"{}"#,
            r#"{"a": 1, "b": {"a": "a", "c": [true, false]}, "z": -0.125}"#,
        ];
        for src in cases {
            let v = Json::parse(src).expect(src);
            let back = bvalue::decode(&bvalue::encode(&v), "test").expect(src);
            assert_eq!(back.dump(), v.dump(), "case {src}");
        }
        // Exact f64 bit preservation for a non-integral value.
        let v = Json::Num(0.1 + 0.2);
        if let Json::Num(n) = bvalue::decode(&bvalue::encode(&v), "test").unwrap() {
            assert_eq!(n.to_bits(), (0.1f64 + 0.2).to_bits());
        } else {
            panic!("expected number");
        }
    }

    #[test]
    fn bvalue_rejects_malformed_payloads() {
        // Empty payload, truncated string table, absurd counts, bad
        // string index — all typed Corrupt, never a panic or OOM.
        for bad in [
            &[][..],
            &[5u8][..],                   // 5 strings promised, nothing follows
            &[0, 0x06, 0xff, 0xff][..],   // array count varint truncated
            &[0, 0x05, 0][..],            // string index into empty table
            &[0, 0x08][..],               // unknown tag
            &[0, 0x03][..],               // int with no varint
            &[0, 0x04, 1, 2][..],         // f64 with 2 of 8 bytes
            &[0, 0x00, 0x00][..],         // trailing byte after value
        ] {
            let err = bvalue::decode(bad, "test").unwrap_err();
            assert!(matches!(err, ArtifactError::Corrupt(_)), "{bad:?}: {err}");
        }
    }

    #[test]
    fn bin_roundtrip_is_bit_identical() {
        let a = build_small();
        let bytes = a.to_bin();
        let back = Artifact::from_bytes(&bytes).expect("bin roundtrip");
        assert_eq!(back.compiled.program, a.compiled.program);
        assert_eq!(back.compiled.plan, a.compiled.plan);
        assert_eq!(back.schedules, a.schedules);
        assert_eq!(back.fingerprint(), a.fingerprint());
        // Re-encoding the decoded artifact is byte-identical in both
        // codecs: the envelope is canonical.
        assert_eq!(back.to_bin(), bytes);
        assert_eq!(back.to_json().pretty(), a.to_json().pretty());
    }

    #[test]
    fn sniffer_selects_codec_by_content_not_extension() {
        let a = build_small();
        // JSON body with leading whitespace still parses.
        let mut json = b"  \n\t".to_vec();
        json.extend_from_slice((a.to_json().pretty() + "\n").as_bytes());
        assert_eq!(Artifact::from_bytes(&json).unwrap().fingerprint(), a.fingerprint());
        // Binary body parses regardless of how the file was named.
        assert_eq!(Artifact::from_bytes(&a.to_bin()).unwrap().fingerprint(), a.fingerprint());
        // Neither: typed NotAnArtifact / Corrupt, never a guess.
        assert_eq!(Artifact::from_bytes(b"PNG\x89 not ours").unwrap_err(), ArtifactError::NotAnArtifact);
        assert_eq!(Artifact::from_bytes(b"SNFLK").unwrap_err(), ArtifactError::NotAnArtifact);
        assert!(matches!(Artifact::from_bytes(b"   ").unwrap_err(), ArtifactError::Corrupt(_)));
    }

    #[test]
    fn bin_version_mismatch_is_typed_before_payload_decode() {
        let a = build_small();
        let mut bytes = a.to_bin();
        bytes[8..16].copy_from_slice(&99u64.to_le_bytes());
        assert_eq!(
            Artifact::from_bytes(&bytes).unwrap_err(),
            ArtifactError::FormatVersion { found: 99, expected: FORMAT_VERSION }
        );
    }

    #[test]
    fn bin_truncation_and_bitflips_are_typed_errors() {
        let a = build_small();
        let bytes = a.to_bin();
        // Truncations at every header/table boundary and a few payload
        // offsets: always a typed error (NotAnArtifact for a cut magic,
        // Corrupt elsewhere), never a panic.
        for cut in [0, 4, 8, 12, 16, 24, 31, 32, 56, 32 + 8 * 24, bytes.len() - 1] {
            let err = Artifact::from_bytes(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, ArtifactError::Corrupt(_) | ArtifactError::NotAnArtifact),
                "cut at {cut}: {err}"
            );
        }
        // A flipped bit inside the first payload breaks that section's
        // checksum before any decoding happens.
        let mut flipped = bytes.clone();
        let at = 32 + 8 * 24; // first payload byte
        flipped[at] ^= 0x01;
        let err = Artifact::from_bytes(&flipped).unwrap_err();
        assert!(matches!(err, ArtifactError::Corrupt(_)), "{err}");
    }

    #[test]
    fn lz_roundtrips_and_compresses_repetitive_payloads() {
        // Shaped like the instruction stream: repeating 16-byte blocks
        // where only one "address" field changes per block.
        let mut data = Vec::new();
        for i in 0u32..4096 {
            data.extend_from_slice(&0x1000_0000u32.to_le_bytes());
            data.extend_from_slice(&(0x2000_0000u32 + i * 64).to_le_bytes());
            data.extend_from_slice(&0x3000_0040u32.to_le_bytes());
            data.extend_from_slice(&0xc000_0123u32.to_le_bytes());
        }
        let packed = lz::compress(&data);
        assert_eq!(lz::decompress(&packed, "test").unwrap(), data);
        assert!(
            packed.len() * 2 < data.len(),
            "block-repetitive data must compress at least 2x: {} vs {}",
            packed.len(),
            data.len()
        );
        // Deterministic: same input, same bytes (to_bin canonicality).
        assert_eq!(lz::compress(&data), packed);

        // Noisy data still round-trips (worst case degrades to literal
        // runs, one control byte per 128).
        let mut x = 0x9e37_79b9_7f4a_7c15u64;
        let noisy: Vec<u8> = (0..10_000)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 56) as u8
            })
            .collect();
        assert_eq!(lz::decompress(&lz::compress(&noisy), "test").unwrap(), noisy);

        // Degenerate shapes: empty, and a one-byte period (overlapping
        // match copies).
        assert_eq!(lz::decompress(&lz::compress(&[]), "test").unwrap(), Vec::<u8>::new());
        let same = vec![7u8; 1000];
        assert_eq!(lz::decompress(&lz::compress(&same), "test").unwrap(), same);
    }

    #[test]
    fn lz_rejects_malformed_streams() {
        let data = b"abcdabcdabcdabcdabcd";
        let good = lz::compress(data);
        assert_eq!(lz::decompress(&good, "test").unwrap(), data.to_vec());
        // Every strict prefix fails the final raw-length accounting (or
        // an earlier bounds check) — typed, never a panic.
        for cut in 0..good.len() {
            assert!(
                lz::decompress(&good[..cut], "test").is_err(),
                "truncation to {cut}/{} bytes must fail",
                good.len()
            );
        }
        // Match reaching before the start of the decoded output.
        assert!(lz::decompress(&[4, 0x80, 1, 0], "test").is_err());
        // Zero match offset.
        assert!(lz::decompress(&[4, 0x00, b'x', 0x80, 0, 0], "test").is_err());
        // Literal run overflowing the declared raw length.
        assert!(lz::decompress(&[1, 1, b'a', b'b'], "test").is_err());
        // Declared raw length impossible for the payload size.
        assert!(lz::decompress(&[0xff, 0xff, 0xff, 0xff, 0x7f], "test").is_err());
    }
}
