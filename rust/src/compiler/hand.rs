//! Hand-optimized baselines for Table 1.
//!
//! The paper compares compiler output against manually written streams
//! whose advantages are "manual optimizations such as filling branch
//! delay slots and instruction reordering" (§6.1). We reproduce the
//! same contrast mechanically: the *hand* variant enables the
//! delay-slot-filling and tighter scheduling paths the paper's authors
//! applied by hand (`smart_delay_slots`), while the *auto* variant pads
//! slots with no-ops — which is why auto carries a few hundred more
//! instructions yet matches execution time wherever MAC latency hides
//! the issue overhead (the paper's Table 1 observation).

use super::{compile_impl, CompileError, CompileOptions, CompiledModel};
use crate::arch::SnowflakeConfig;
use crate::model::graph::Graph;

/// Compile the "auto" variant (the paper's compiler-generated code).
pub fn compile_auto(g: &Graph, cfg: &SnowflakeConfig) -> Result<CompiledModel, CompileError> {
    compile_impl(g, cfg, &CompileOptions { smart_delay_slots: false, ..Default::default() })
}

/// Compile the "hand" variant (manually scheduled slots).
pub fn compile_hand(g: &Graph, cfg: &SnowflakeConfig) -> Result<CompiledModel, CompileError> {
    compile_impl(g, cfg, &CompileOptions { smart_delay_slots: true, ..Default::default() })
}

/// Instruction-count delta (auto − hand), the paper's "437 more".
pub fn instr_delta(auto: &CompiledModel, hand: &CompiledModel) -> i64 {
    auto.code_len as i64 - hand.code_len as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn hand_is_shorter_than_auto() {
        let cfg = SnowflakeConfig::default();
        for g in zoo::table1_layers() {
            let auto = compile_auto(&g, &cfg).unwrap();
            let hand = compile_hand(&g, &cfg).unwrap();
            assert!(instr_delta(&auto, &hand) >= 0, "{}", g.name);
        }
    }
}
