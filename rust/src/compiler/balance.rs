//! Communication load balancing (§6.3 / Table 3).
//!
//! "Snowflake has 4 load/store units, and properly distributing LD
//! instructions to all units prevents CU stalls due to data transfer …
//! A better approach is to break the maps data into multiple load
//! instructions and distribute evenly with the kernel loads."
//!
//! `UnitAllocator` is threaded through code generation: every emitted LD
//! asks it for a unit, and the greedy policy keeps a running byte count
//! per unit so the heaviest stream never piles onto one port. The
//! policies reproduce the imbalance spectrum of Table 3.

use super::BalancePolicy;

/// Assigns load units to LD instructions at code-generation time.
#[derive(Clone, Debug)]
pub struct UnitAllocator {
    policy: BalancePolicy,
    bytes: Vec<u64>,
    rr: usize,
}

/// Coarse stream classes (the TwoUnits policy pins by class).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamClass {
    Maps,
    Weights,
    Bias,
    ICache,
}

impl UnitAllocator {
    pub fn new(policy: BalancePolicy, n_units: usize) -> Self {
        UnitAllocator { policy, bytes: vec![0; n_units], rr: 0 }
    }

    /// Switch the assignment policy mid-stream (per-layer tuned
    /// policies). The running byte counters are kept, so Greedy keeps
    /// balancing across layer boundaries.
    pub fn set_policy(&mut self, policy: BalancePolicy) {
        self.policy = policy;
    }

    /// How many pieces to split a maps stream into.
    pub fn map_split(&self) -> usize {
        match self.policy {
            BalancePolicy::Greedy { split } => split.max(1),
            _ => 1,
        }
    }

    /// Pick a unit for a stream of `words` 16-bit words.
    pub fn unit_for(&mut self, class: StreamClass, words: usize) -> u8 {
        let n = self.bytes.len();
        let u = match self.policy {
            BalancePolicy::OneUnit => 0,
            BalancePolicy::TwoUnits => match class {
                // The paper's worst measured case: "kernel and maps uses
                // two load units".
                StreamClass::Maps | StreamClass::ICache => 0,
                StreamClass::Weights | StreamClass::Bias => 1 % n,
            },
            BalancePolicy::Greedy { .. } => {
                // Least-loaded unit; round-robin tie-break.
                let mut best = 0;
                let mut best_b = u64::MAX;
                for i in 0..n {
                    let idx = (self.rr + i) % n;
                    if self.bytes[idx] < best_b {
                        best_b = self.bytes[idx];
                        best = idx;
                    }
                }
                self.rr = (best + 1) % n;
                best
            }
        };
        self.bytes[u] += (words * 2) as u64;
        u as u8
    }

    /// Static byte counters (codegen-side estimate of the imbalance the
    /// run will show).
    pub fn planned_imbalance_pct(&self) -> f64 {
        let total: u64 = self.bytes.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let mean = total as f64 / self.bytes.len() as f64;
        let max = *self.bytes.iter().max().unwrap() as f64;
        (max / mean - 1.0) * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_balances_bytes() {
        let mut a = UnitAllocator::new(BalancePolicy::Greedy { split: 2 }, 4);
        for i in 0..100 {
            let words = 100 + (i % 7) * 30;
            a.unit_for(if i % 3 == 0 { StreamClass::Maps } else { StreamClass::Weights }, words);
        }
        assert!(a.planned_imbalance_pct() < 10.0, "{}", a.planned_imbalance_pct());
    }

    #[test]
    fn one_unit_is_maximally_imbalanced() {
        let mut a = UnitAllocator::new(BalancePolicy::OneUnit, 4);
        for _ in 0..10 {
            a.unit_for(StreamClass::Maps, 100);
        }
        assert!((a.planned_imbalance_pct() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn two_units_split_by_class() {
        let mut a = UnitAllocator::new(BalancePolicy::TwoUnits, 4);
        assert_eq!(a.unit_for(StreamClass::Maps, 10), 0);
        assert_eq!(a.unit_for(StreamClass::Weights, 10), 1);
        assert_eq!(a.unit_for(StreamClass::Maps, 10), 0);
        assert!(a.planned_imbalance_pct() > 90.0);
    }

    #[test]
    fn split_factor_from_policy() {
        assert_eq!(UnitAllocator::new(BalancePolicy::Greedy { split: 4 }, 4).map_split(), 4);
        assert_eq!(UnitAllocator::new(BalancePolicy::OneUnit, 4).map_split(), 1);
    }

    /// Pin the Greedy selection contract: strictly least-loaded unit
    /// wins; byte-count ties break round-robin starting after the last
    /// winner, so equal streams rotate fairly across all units.
    #[test]
    fn greedy_least_loaded_and_round_robin_tie_break() {
        let mut a = UnitAllocator::new(BalancePolicy::Greedy { split: 2 }, 4);
        // All-zero counters: ties rotate 0, 1, 2, 3.
        assert_eq!(a.unit_for(StreamClass::Maps, 10), 0);
        assert_eq!(a.unit_for(StreamClass::Weights, 10), 1);
        assert_eq!(a.unit_for(StreamClass::Weights, 10), 2);
        assert_eq!(a.unit_for(StreamClass::Bias, 10), 3);
        // Equal again: the rotation wraps to unit 0.
        assert_eq!(a.unit_for(StreamClass::Maps, 10), 0);
        // A heavy stream loads unit 1; subsequent equal-byte ties must
        // keep rotating among the (now lighter) others…
        assert_eq!(a.unit_for(StreamClass::Maps, 100), 1);
        assert_eq!(a.unit_for(StreamClass::Weights, 10), 2);
        // …and the strictly least-loaded unit (3, untouched since its
        // first 10-word stream) wins over the rotation order.
        assert_eq!(a.unit_for(StreamClass::Weights, 10), 3);
        // Unit 1 (the heavy one) is only chosen again once it is no
        // longer strictly heavier than every alternative.
        let picks: Vec<u8> = (0..4).map(|_| a.unit_for(StreamClass::Maps, 10)).collect();
        assert!(!picks.contains(&1), "heavy unit picked while lighter ones exist: {picks:?}");
    }

    #[test]
    fn policy_switch_keeps_byte_counters() {
        let mut a = UnitAllocator::new(BalancePolicy::Greedy { split: 2 }, 4);
        a.unit_for(StreamClass::Maps, 100);
        a.set_policy(BalancePolicy::OneUnit);
        assert_eq!(a.map_split(), 1);
        a.unit_for(StreamClass::Maps, 100);
        a.set_policy(BalancePolicy::Greedy { split: 4 });
        assert_eq!(a.map_split(), 4);
        // Counters survived both switches: unit 0 carries 400 bytes, so
        // greedy avoids it.
        assert_ne!(a.unit_for(StreamClass::Maps, 10), 0);
    }
}
