//! Memory layout planning: layer lowering, device data layout and the
//! CMA-style region allocator (§5.3: "All data need to be placed into
//! CMA allocated region of memory. Different regions in CMA are
//! allocated according to layer dependencies").
//!
//! ## Device data layout
//!
//! Activations live in DRAM as **interleaved padded canvases**: element
//! `(c, y, x)` of a C×H×W tensor sits at
//! `base + ((y + mp) * w_canvas + (x + mp)) * c_pad + c`, where
//! `c_pad` rounds channels up (to 16, or to 4 below 16) and `mp` is the
//! maximum spatial padding any consumer needs. Zero margins make every
//! convolution window a *contiguous trace* (the paper's §2 "trace: any
//! contiguous sequence of multiply and accumulate") regardless of
//! padding, and channel interleaving makes one 16-lane vector word = 16
//! channels of one pixel — the COOP vMAC's natural diet. Storing the
//! overlap/margin once in DRAM mirrors the paper's storing of
//! overlapped regions (§2, vs [1]'s augmented tiles).

use super::decide::{self, OpPlan};
use super::{CompileError, CompileOptions};
use crate::arch::SnowflakeConfig;
use crate::fixed::QFormat;
use crate::model::graph::Graph;
use crate::model::layer::LayerKind;
use std::collections::BTreeMap;

/// Channel padding rule: vector-lane multiple for real layers, 4 for
/// the tiny network input (3 channels).
pub fn c_pad(c: usize) -> usize {
    if c >= 16 {
        c.div_ceil(16) * 16
    } else {
        c.div_ceil(4) * 4
    }
}

/// An activation canvas in DRAM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Canvas {
    pub base: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub c_pad: usize,
    /// Margin (max consumer pad) on top/left/bottom/right.
    pub mp: usize,
    /// Extra rows below the margin for tiling overshoot.
    pub h_slack: usize,
    /// Extra columns right of the margin for padded-trace overreach.
    pub w_slack: usize,
}

impl Canvas {
    pub fn w_canvas(&self) -> usize {
        self.w + 2 * self.mp + self.w_slack
    }

    pub fn h_canvas(&self) -> usize {
        self.h + 2 * self.mp + self.h_slack
    }

    pub fn words(&self) -> usize {
        self.w_canvas() * self.h_canvas() * self.c_pad
    }

    /// DRAM word address of interior element (c, y, x).
    pub fn addr(&self, c: usize, y: usize, x: usize) -> usize {
        debug_assert!(c < self.c_pad && y < self.h && x < self.w);
        self.addr_u(c, y, x)
    }

    /// Interior addressing without bounds assertions (tiling overshoot
    /// rows land in the allocated slack).
    pub fn addr_u(&self, c: usize, y: usize, x: usize) -> usize {
        self.base + ((y + self.mp) * self.w_canvas() + (x + self.mp)) * self.c_pad + c
    }

    /// DRAM word address of canvas row `cy` (no margin offset), col 0.
    pub fn raw_row(&self, cy: usize) -> usize {
        self.base + cy * self.w_canvas() * self.c_pad
    }

    /// Words per canvas row.
    pub fn row_words(&self) -> usize {
        self.w_canvas() * self.c_pad
    }
}

/// A lowered operation: graph nodes after fusing ResidualAdd into its
/// producing conv (§2 Residual addition: "add those bypass values as
/// output results are being produced by a CONV").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lowered {
    Conv {
        node: usize,
        /// Producer node (None = network input).
        src: Option<usize>,
        /// Bypass tensor node (fused residual add).
        bypass: Option<usize>,
        in_ch: usize,
        out_ch: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        relu: bool,
    },
    MaxPool { node: usize, src: Option<usize>, kh: usize, kw: usize, stride: usize, pad: usize },
    AvgPool { node: usize, src: Option<usize>, kh: usize, kw: usize, stride: usize, pad: usize },
    Fc { node: usize, src: Option<usize>, in_features: usize, out_features: usize, relu: bool },
}

impl Lowered {
    /// Graph node whose output canvas this op writes.
    pub fn out_node(&self) -> usize {
        match *self {
            Lowered::Conv { node, bypass, .. } => {
                // Fused conv writes the *residual node's* canvas.
                if bypass.is_some() {
                    node
                } else {
                    node
                }
            }
            Lowered::MaxPool { node, .. }
            | Lowered::AvgPool { node, .. }
            | Lowered::Fc { node, .. } => node,
        }
    }

    pub fn src(&self) -> Option<usize> {
        match *self {
            Lowered::Conv { src, .. }
            | Lowered::MaxPool { src, .. }
            | Lowered::AvgPool { src, .. }
            | Lowered::Fc { src, .. } => src,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Lowered::Conv { bypass: Some(_), .. } => "conv+res",
            Lowered::Conv { .. } => "conv",
            Lowered::MaxPool { .. } => "maxpool",
            Lowered::AvgPool { .. } => "avgpool",
            Lowered::Fc { .. } => "fc",
        }
    }
}

/// Lower the graph: fuse residual adds, reject layers the hardware has
/// no path for.
pub fn lower(graph: &Graph) -> Result<Vec<Lowered>, CompileError> {
    // Which conv feeds which residual (conv must be input[0] and only
    // consumed by the residual).
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); graph.nodes.len()];
    for n in &graph.nodes {
        for &p in &n.inputs {
            consumers[p].push(n.id);
        }
    }
    let mut fused_into: BTreeMap<usize, usize> = BTreeMap::new(); // conv -> residual node
    for n in &graph.nodes {
        if let LayerKind::ResidualAdd { .. } = n.kind {
            let main = n.inputs[0];
            let fusable = matches!(graph.nodes[main].kind, LayerKind::Conv { .. })
                && consumers[main].len() == 1;
            if !fusable {
                return Err(CompileError(format!(
                    "residual node {} cannot be fused into its producer (node {}): the hardware \
                     adds bypass values only on CONV writeback",
                    n.id, main
                )));
            }
            fused_into.insert(main, n.id);
        }
    }

    let mut out = Vec::new();
    for n in &graph.nodes {
        let src = n.inputs.first().copied();
        match n.kind {
            LayerKind::Conv { relu, .. } => {
                if fused_into.contains_key(&n.id) {
                    // Emitted at the residual node's position so every
                    // input (notably the bypass, e.g. a downsample conv)
                    // is computed first.
                    if relu {
                        return Err(CompileError(format!(
                            "conv node {} has relu before a fused residual add",
                            n.id
                        )));
                    }
                    continue;
                }
                let LayerKind::Conv { in_ch, out_ch, kh, kw, stride, pad, relu } = n.kind else {
                    unreachable!()
                };
                out.push(Lowered::Conv {
                    node: n.id,
                    src,
                    bypass: None,
                    in_ch,
                    out_ch,
                    kh,
                    kw,
                    stride,
                    pad,
                    relu,
                });
            }
            LayerKind::MaxPool { kh, kw, stride, pad } => {
                out.push(Lowered::MaxPool { node: n.id, src, kh, kw, stride, pad })
            }
            LayerKind::AvgPool { kh, kw, stride, pad } => {
                out.push(Lowered::AvgPool { node: n.id, src, kh, kw, stride, pad })
            }
            LayerKind::Fc { in_features, out_features, relu } => {
                out.push(Lowered::Fc { node: n.id, src, in_features, out_features, relu })
            }
            LayerKind::ResidualAdd { relu } => {
                // The fused conv runs here, writing this node's canvas.
                let conv = n.inputs[0];
                let LayerKind::Conv { in_ch, out_ch, kh, kw, stride, pad, .. } =
                    graph.nodes[conv].kind
                else {
                    unreachable!("fusability checked above")
                };
                out.push(Lowered::Conv {
                    node: n.id,
                    src: graph.nodes[conv].inputs.first().copied(),
                    bypass: Some(n.inputs[1]),
                    in_ch,
                    out_ch,
                    kh,
                    kw,
                    stride,
                    pad,
                    relu,
                });
            }
            LayerKind::Relu => {
                return Err(CompileError(format!(
                    "standalone relu node {} survived parsing; the hardware applies ReLU on \
                     writeback only",
                    n.id
                )))
            }
        }
    }
    Ok(out)
}

/// Per-lowered-op plan entry.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    pub op: Lowered,
    pub decision: OpPlan,
    /// DRAM base of arranged weights (0 words if none).
    pub weights_addr: usize,
    pub weights_words: usize,
    /// DRAM base of bias array.
    pub bias_addr: usize,
    pub bias_words: usize,
}

/// The full memory plan.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub fmt: QFormat,
    pub input_canvas: Canvas,
    /// node id -> output canvas.
    pub canvases: BTreeMap<usize, Canvas>,
    pub layers: Vec<LayerPlan>,
    /// 64 guaranteed-zero words (avgpool bias clear etc).
    pub zero_addr: usize,
    /// Where the encoded instruction stream goes (codegen fills length).
    pub program_addr: usize,
    /// Total DRAM words (after codegen adds the stream image).
    pub mem_words: usize,
    /// Peak activation words (reporting; exercised by region reuse).
    pub activation_words: usize,
}

impl Plan {
    /// Canvas a lowered op reads (input canvas when src is None).
    pub fn in_canvas(&self, op: &Lowered) -> Canvas {
        match op.src() {
            None => self.input_canvas,
            Some(p) => self.canvases[&p],
        }
    }

    pub fn out_canvas(&self, op: &Lowered) -> Canvas {
        self.canvases[&op.out_node()]
    }

    /// The conv schedules this plan actually used, keyed by lowered
    /// node id — the replayable form an [`super::Artifact`] records and
    /// the measured tuner refines.
    pub fn conv_schedules(&self) -> super::ScheduleMap {
        self.layers
            .iter()
            .filter_map(|lp| {
                let OpPlan::Conv(d) = &lp.decision else { return None };
                Some((
                    lp.op.out_node(),
                    super::cost::Schedule {
                        order: d.order,
                        rows_per_cu: d.rows_per_cu,
                        policy: d.policy,
                    },
                ))
            })
            .collect()
    }
}

/// Build the plan: lower, decide, size canvases (margins + slack),
/// allocate regions, place weights/biases.
pub fn plan(
    graph: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> Result<Plan, CompileError> {
    let lowered = lower(graph)?;
    let shapes = graph.shapes();

    // Consumer pads per producing node (margins).
    let mut mp: BTreeMap<Option<usize>, usize> = BTreeMap::new();
    for op in &lowered {
        let p = match *op {
            Lowered::Conv { pad, .. } => pad,
            Lowered::MaxPool { pad, .. } | Lowered::AvgPool { pad, .. } => pad,
            Lowered::Fc { .. } => 0,
        };
        let e = mp.entry(op.src()).or_insert(0);
        *e = (*e).max(p);
    }

    // Shapes per lowered op.
    let in_shape = |op: &Lowered| match op.src() {
        None => graph.input,
        Some(p) => shapes[p],
    };

    // Column-slack pre-pass (pure geometry): padded traces / strided
    // lane reads may overrun the input canvas width.
    let mut w_slack: BTreeMap<Option<usize>, usize> = BTreeMap::new();
    for op in &lowered {
        let is_ = in_shape(op);
        let os_ = shapes[op.out_node()];
        let sl = match *op {
            Lowered::Conv { kw, stride, pad, .. } => {
                decide::conv_geometry(is_, kw, stride, pad, os_.w).in_w_slack
            }
            Lowered::MaxPool { kw, stride, pad, .. } => {
                decide::pool_geometry(is_, kw, stride, pad, os_.w)
            }
            _ => 0,
        };
        let e = w_slack.entry(op.src()).or_insert(0);
        *e = (*e).max(sl);
    }

    // Decisions (step 3) given final canvas geometry.
    let mut decisions = Vec::new();
    for op in &lowered {
        let is_ = in_shape(op);
        let os_ = shapes[op.out_node()];
        let in_mp = *mp.get(&op.src()).unwrap_or(&0);
        let in_ws = *w_slack.get(&op.src()).unwrap_or(&0);
        decisions.push(decide::decide(op, is_, os_, in_mp, in_ws, cfg, opts)?);
    }

    // Row-slack pass: writer overshoot rows + consumer overread.
    let mut h_slack: BTreeMap<Option<usize>, usize> = BTreeMap::new();
    for (op, d) in lowered.iter().zip(&decisions) {
        // Writer overshoot on the *output* canvas.
        let os_ = shapes[op.out_node()];
        let written_rows = d.n_tiles() * d.rows_per_cu() * cfg.n_cus;
        let over = written_rows.saturating_sub(os_.h);
        let e = h_slack.entry(Some(op.out_node())).or_insert(0);
        *e = (*e).max(over);
        // Reader overread on the *input* canvas: rows needed by the last
        // (overshooting) output row.
        let is_ = in_shape(op);
        let need_rows = d.in_rows_needed(written_rows);
        let over_in = need_rows.saturating_sub(is_.h + 2 * d.pad());
        let e = h_slack.entry(op.src()).or_insert(0);
        *e = (*e).max(over_in);
        // A fused bypass reads one row of its canvas per output row,
        // including overshoot rows.
        if let Lowered::Conv { bypass: Some(b), .. } = op {
            let e = h_slack.entry(Some(*b)).or_insert(0);
            *e = (*e).max(over);
        }
    }

    // Region allocation (bump; optional reuse of Sequential regions).
    let mut cursor = 64usize; // leave page 0 for the zero region
    let zero_addr = 0usize;
    let mut alloc = |words: usize| {
        let base = cursor;
        cursor += words.div_ceil(64) * 64;
        base
    };

    let mk_canvas = |base: usize, c: usize, h: usize, w: usize, src: Option<usize>| Canvas {
        base,
        c,
        h,
        w,
        c_pad: c_pad(c),
        mp: *mp.get(&src).unwrap_or(&0),
        h_slack: *h_slack.get(&src).unwrap_or(&0) + 1, // +1 pool spill row
        w_slack: *w_slack.get(&src).unwrap_or(&0),
    };

    // Input canvas.
    let mut input_canvas = mk_canvas(0, graph.input.c, graph.input.h, graph.input.w, None);
    input_canvas.base = alloc(input_canvas.words());

    // Node canvases. With reuse on, a Sequential node's region is freed
    // after its last consumer and recycled (simple free-list).
    let mut canvases: BTreeMap<usize, Canvas> = BTreeMap::new();
    let mut free: Vec<(usize, usize)> = Vec::new(); // (base, words)
    let mut last_use: BTreeMap<usize, usize> = BTreeMap::new();
    for n in &graph.nodes {
        for &p in &n.inputs {
            last_use.insert(p, n.id);
        }
    }
    let mut activation_words = input_canvas.words();
    let out_nodes: Vec<usize> = lowered.iter().map(|o| o.out_node()).collect();
    for (op, _) in lowered.iter().zip(&decisions) {
        let node = op.out_node();
        let s = shapes[node];
        let mut cv = mk_canvas(0, s.c, s.h, s.w, Some(node));
        let words = cv.words();
        cv.base = if opts.reuse_regions {
            match free.iter().position(|&(_, w)| w >= words) {
                Some(i) => {
                    let (base, w) = free.remove(i);
                    if w > words {
                        free.push((base + words, w - words));
                    }
                    base
                }
                None => alloc(words),
            }
        } else {
            alloc(words)
        };
        activation_words += words;
        canvases.insert(node, cv);
        if opts.reuse_regions {
            // Free canvases whose last consumer is this node.
            for (&p, &lu) in last_use.iter() {
                if lu == node && p != node {
                    if let Some(c) = canvases.get(&p) {
                        // Never free a canvas another pending op reads.
                        let still_needed = out_nodes
                            .iter()
                            .zip(&lowered)
                            .any(|(&on, o)| on > node && o.src() == Some(p));
                        if !still_needed {
                            free.push((c.base, c.words()));
                        }
                    }
                }
            }
        }
    }

    // Weights + biases.
    let mut layers = Vec::new();
    for (op, d) in lowered.iter().zip(decisions) {
        let (w_words, b_words) = d.weight_bias_words();
        let weights_addr = if w_words > 0 { alloc(w_words) } else { 0 };
        let bias_addr = if b_words > 0 { alloc(b_words) } else { 0 };
        layers.push(LayerPlan {
            op: op.clone(),
            decision: d,
            weights_addr,
            weights_words: w_words,
            bias_addr,
            bias_words: b_words,
        });
    }

    let program_addr = alloc(0);
    Ok(Plan {
        fmt: opts.fmt,
        input_canvas,
        canvases,
        layers,
        zero_addr,
        program_addr,
        mem_words: program_addr, // codegen extends by the stream image
        activation_words,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn c_pad_rules() {
        assert_eq!(c_pad(3), 4);
        assert_eq!(c_pad(4), 4);
        assert_eq!(c_pad(15), 16);
        assert_eq!(c_pad(16), 16);
        assert_eq!(c_pad(17), 32);
        assert_eq!(c_pad(192), 192);
        assert_eq!(c_pad(1000), 1008);
    }

    #[test]
    fn canvas_addressing() {
        let cv = Canvas { base: 100, c: 3, h: 4, w: 5, c_pad: 4, mp: 1, h_slack: 0, w_slack: 0 };
        assert_eq!(cv.w_canvas(), 7);
        assert_eq!(cv.h_canvas(), 6);
        assert_eq!(cv.words(), 7 * 6 * 4);
        // (0,0,0) sits one margin row + one margin col in.
        assert_eq!(cv.addr(0, 0, 0), 100 + (7 + 1) * 4);
        assert_eq!(cv.addr(2, 3, 4), 100 + ((3 + 1) * 7 + 5) * 4 + 2);
    }

    #[test]
    fn lowering_fuses_residuals() {
        let g = zoo::resnet18();
        let l = lower(&g).unwrap();
        let fused = l.iter().filter(|o| o.name() == "conv+res").count();
        assert_eq!(fused, 8); // one per basic block
        // No lowered op for the residual nodes themselves.
        assert_eq!(
            l.len(),
            g.nodes.len() - 8,
            "residuals folded into their convs"
        );
    }

    #[test]
    fn alexnet_plan_allocates_disjoint_regions() {
        let g = zoo::alexnet_owt();
        let cfg = SnowflakeConfig::default();
        let p = plan(&g, &cfg, &CompileOptions::default()).unwrap();
        // All canvases + weight regions disjoint.
        let mut spans: Vec<(usize, usize, String)> = Vec::new();
        spans.push((p.input_canvas.base, p.input_canvas.words(), "input".into()));
        for (n, c) in &p.canvases {
            spans.push((c.base, c.words(), format!("canvas{n}")));
        }
        for l in &p.layers {
            if l.weights_words > 0 {
                spans.push((l.weights_addr, l.weights_words, format!("w{}", l.op.out_node())));
                spans.push((l.bias_addr, l.bias_words, format!("b{}", l.op.out_node())));
            }
        }
        spans.sort();
        for pair in spans.windows(2) {
            assert!(
                pair[0].0 + pair[0].1 <= pair[1].0,
                "{} overlaps {}",
                pair[0].2,
                pair[1].2
            );
        }
        assert!(p.mem_words > 0);
    }

    #[test]
    fn reuse_regions_shrinks_footprint() {
        let g = zoo::alexnet_owt();
        let cfg = SnowflakeConfig::default();
        let p1 = plan(&g, &cfg, &CompileOptions::default()).unwrap();
        let p2 = plan(
            &g,
            &cfg,
            &CompileOptions { reuse_regions: true, ..Default::default() },
        )
        .unwrap();
        assert!(p2.mem_words < p1.mem_words, "{} !< {}", p2.mem_words, p1.mem_words);
    }
}
