//! Pipeline-parallel model partitioning (multi-machine sharding).
//!
//! Splits a model graph into N *contiguous* layer ranges — pipeline
//! stages — each compiled into its own [`Artifact`] for its own
//! accelerator, plus a versioned [`ShardPlan`] manifest recording the
//! stage boundaries, the inter-stage activation shapes/bytes and the
//! per-stage artifact fingerprints. The cluster runtime
//! (`engine::cluster`) deploys one machine per stage and forwards each
//! boundary activation over a modeled inter-machine link.
//!
//! Two invariants make sharding *transparent*:
//!
//! 1. **Bit-identity.** A cut is only *feasible* when every edge that
//!    crosses it leaves the node directly before the cut and lands in a
//!    single-input consumer. The consuming stage then reads the shipped
//!    activation as its network input, and the producing stage's output
//!    canvas words are copied verbatim (`deploy::write_canvas_i16`) —
//!    no re-quantization, so N machines compute exactly what one
//!    machine computes at the same layer boundary.
//! 2. **Balance.** The partitioner minimizes the *bottleneck* stage
//!    (max per-stage predicted cycles from the compiler's cost model),
//!    which bounds steady-state pipeline throughput. Seeds (even layer
//!    split + cost-greedy split) are refined by deterministic local
//!    moves, so the result is never worse than the even split.

use super::artifact::{self, config_hash, Artifact, ArtifactFormat};
use super::{CompileOptions, Compiler};
use crate::arch::SnowflakeConfig;
use crate::model::graph::Graph;
use crate::model::layer::{LayerKind, Shape};
use crate::model::parser;
use crate::model::weights::Weights;
use crate::util::json::Json;
use std::path::Path;

/// Current manifest format version. Bump on incompatible change.
pub const SHARDPLAN_VERSION: u64 = 1;
const MAGIC: &str = "snowflake-shardplan";

/// Partitioning / manifest failure.
#[derive(Debug, Clone)]
pub struct PartitionError(pub String);

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "partition error: {}", self.0)
    }
}

impl std::error::Error for PartitionError {}

fn perr<E: std::fmt::Display>(e: E) -> PartitionError {
    PartitionError(e.to_string())
}

/// The activation tensor shipped across one inter-stage link: the
/// logical CHW interior of the boundary node's canvas (padding and
/// margins are a per-machine layout concern and never travel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Boundary {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Boundary {
    fn of(s: Shape) -> Boundary {
        Boundary { c: s.c, h: s.h, w: s.w }
    }

    /// i16 words shipped per inference.
    pub fn words(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Bytes on the wire per inference.
    pub fn bytes(&self, cfg: &SnowflakeConfig) -> u64 {
        (self.words() * cfg.word_bytes) as u64
    }
}

/// Cycles to move `bytes` over the inter-machine link: one DMA setup
/// plus the serialization time at [`SnowflakeConfig::link_bytes_per_cycle`].
/// Millibyte-per-cycle fixed point keeps the division exact and
/// platform-independent (same scheme as the DMA engine's rates).
pub fn link_cycles(cfg: &SnowflakeConfig, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    let millibytes_per_cycle = ((cfg.link_bytes_per_cycle() * 1000.0).round() as u64).max(1);
    cfg.dma_setup_cycles + (bytes * 1000).div_ceil(millibytes_per_cycle)
}

/// One pipeline stage: a compiled contiguous layer range.
#[derive(Clone, Debug)]
pub struct Stage {
    /// First full-graph node id in the stage (inclusive).
    pub start: usize,
    /// One past the last full-graph node id (exclusive).
    pub end: usize,
    /// The stage's own compiled artifact (stage-local node ids).
    pub artifact: Artifact,
    /// Cost-model prediction for this stage ([`Artifact::predicted_cycles`]).
    pub predicted_cycles: u64,
    /// Activation shipped to the next stage (None for the final stage).
    pub boundary: Option<Boundary>,
}

/// A partitioned model: the full graph, the target config and one
/// compiled [`Stage`] per machine. Serialized as a versioned manifest
/// plus sibling per-stage artifact files.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub cfg: SnowflakeConfig,
    /// The unpartitioned model (boundary oracle + provenance).
    pub graph: Graph,
    pub stages: Vec<Stage>,
}

impl ShardPlan {
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Cut positions in full-graph node ids (empty for one stage).
    pub fn cuts(&self) -> Vec<usize> {
        self.stages.iter().skip(1).map(|s| s.start).collect()
    }

    /// Per-stage predicted cycles, in stage order.
    pub fn stage_cycles(&self) -> Vec<u64> {
        self.stages.iter().map(|s| s.predicted_cycles).collect()
    }

    /// Predicted link cycles per boundary (one per stage minus one).
    pub fn link_cycles(&self) -> Vec<u64> {
        self.stages
            .iter()
            .filter_map(|s| s.boundary)
            .map(|b| link_cycles(&self.cfg, b.bytes(&self.cfg)))
            .collect()
    }

    /// Predicted *sequential* end-to-end cycles for one inference:
    /// every stage plus every link, no pipeline overlap. This is the
    /// per-request latency the cluster reports and the serving policies
    /// budget against.
    pub fn predicted_cycles(&self) -> u64 {
        self.stage_cycles().iter().sum::<u64>() + self.link_cycles().iter().sum::<u64>()
    }

    /// The bottleneck stage's predicted cycles — the steady-state
    /// pipeline initiation interval (lower is faster).
    pub fn bottleneck_cycles(&self) -> u64 {
        self.stage_cycles().into_iter().max().unwrap_or(0)
    }

    /// Apportion a deadline over the pipeline: each stage gets its own
    /// in-sim cycle budget `ceil(predicted_cycles × slack)` — the same
    /// cost-model prediction the whole-pipeline budget
    /// (`predicted_cycles() × slack`, links included) is built from, so
    /// the per-stage budgets sum to the stage share of the whole and a
    /// stage that blows its share is named at the exact cycle it does.
    /// Link time is *not* apportioned per stage; effective link cycles
    /// accrue against the whole-pipeline budget as boundaries cross.
    pub fn stage_budgets(&self, slack: f64) -> Vec<u64> {
        self.stages
            .iter()
            .map(|s| (s.predicted_cycles as f64 * slack).ceil() as u64)
            .collect()
    }

    pub fn config_hash(&self) -> u64 {
        config_hash(&self.cfg)
    }

    /// Structural self-check: contiguous full coverage, per-stage node
    /// counts, boundary shapes against the full graph, config binding.
    pub fn validate(&self) -> Result<(), PartitionError> {
        if self.stages.is_empty() {
            return Err(PartitionError("shard plan has no stages".to_string()));
        }
        let n = self.graph.nodes.len();
        let shapes = self.graph.shapes();
        let mut expect = 0usize;
        for (k, st) in self.stages.iter().enumerate() {
            if st.start != expect || st.end <= st.start {
                return Err(PartitionError(format!(
                    "stage {k} covers [{}, {}) but [{expect}, ..) was expected: \
                     stages must tile the graph contiguously",
                    st.start, st.end
                )));
            }
            if st.artifact.graph.nodes.len() != st.end - st.start {
                return Err(PartitionError(format!(
                    "stage {k} artifact has {} nodes but covers {} graph nodes",
                    st.artifact.graph.nodes.len(),
                    st.end - st.start
                )));
            }
            st.artifact.validate_config(&self.cfg).map_err(perr)?;
            let last = k + 1 == self.stages.len();
            match (st.boundary, last) {
                (Some(b), false) => {
                    if b != Boundary::of(shapes[st.end - 1]) {
                        return Err(PartitionError(format!(
                            "stage {k} boundary {}x{}x{} does not match node {} output",
                            b.c,
                            b.h,
                            b.w,
                            st.end - 1
                        )));
                    }
                }
                (None, true) => {}
                (Some(_), true) => {
                    return Err(PartitionError("final stage must not have a boundary".into()))
                }
                (None, false) => {
                    return Err(PartitionError(format!("stage {k} is missing its boundary")))
                }
            }
            expect = st.end;
        }
        if expect != n {
            return Err(PartitionError(format!(
                "stages cover {expect} of {n} graph nodes"
            )));
        }
        Ok(())
    }

    fn manifest_json(&self, stem: &str, formats: &[ArtifactFormat]) -> Json {
        let stages: Vec<Json> = self
            .stages
            .iter()
            .enumerate()
            .map(|(k, st)| {
                Json::obj(vec![
                    ("start", Json::num(st.start as f64)),
                    ("end", Json::num(st.end as f64)),
                    ("file", Json::str(&stage_file(stem, k, formats[k]))),
                    ("format", Json::str(&formats[k].to_string())),
                    ("fingerprint", Json::str(&artifact::hex(st.artifact.fingerprint()))),
                    ("predicted_cycles", Json::num(st.predicted_cycles as f64)),
                    (
                        "boundary",
                        match st.boundary {
                            Some(b) => Json::obj(vec![
                                ("c", Json::num(b.c as f64)),
                                ("h", Json::num(b.h as f64)),
                                ("w", Json::num(b.w as f64)),
                            ]),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("magic", Json::str(MAGIC)),
            ("version", Json::num(SHARDPLAN_VERSION as f64)),
            ("config_hash", Json::str(&artifact::hex(self.config_hash()))),
            ("config", artifact::config_json(&self.cfg)),
            ("model", Json::str(&parser::dump_model(&self.graph))),
            ("stages", Json::Arr(stages)),
        ])
    }

    /// Write the manifest at `path` plus one sibling
    /// `<stem>.stage<k>.artifact.json` per stage (JSON encoding).
    pub fn save(&self, path: &str) -> Result<(), PartitionError> {
        self.save_with_formats(path, |_| ArtifactFormat::Json)
    }

    /// Like [`ShardPlan::save`], but each stage artifact is written in
    /// the encoding `fmt_of(stage_index)` returns, the stage file name
    /// takes that encoding's extension, and the manifest records the
    /// per-stage format. A mixed json/bin stage set is valid: loading
    /// goes through the sniffing [`Artifact::load`], so the recorded
    /// format is provenance, not a dispatch key.
    pub fn save_with_formats(
        &self,
        path: &str,
        fmt_of: impl Fn(usize) -> ArtifactFormat,
    ) -> Result<(), PartitionError> {
        self.validate()?;
        let p = Path::new(path);
        let dir = p.parent().unwrap_or_else(|| Path::new(""));
        let stem = manifest_stem(p);
        let formats: Vec<ArtifactFormat> = (0..self.stages.len()).map(&fmt_of).collect();
        for (k, st) in self.stages.iter().enumerate() {
            let file = dir.join(stage_file(&stem, k, formats[k]));
            st.artifact
                .save_format(&file.to_string_lossy(), formats[k])
                .map_err(perr)?;
        }
        std::fs::write(path, self.manifest_json(&stem, &formats).pretty() + "\n")
            .map_err(|e| PartitionError(format!("{path}: {e}")))
    }

    /// Load a manifest and its stage artifacts, validating the format
    /// version, the config binding against `host`, every recorded
    /// fingerprint and the coverage invariants.
    pub fn load(path: &str, host: &SnowflakeConfig) -> Result<ShardPlan, PartitionError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| PartitionError(format!("{path}: {e}")))?;
        let root = Json::parse(&text).map_err(perr)?;
        if root.get("magic").as_str() != Some(MAGIC) {
            return Err(PartitionError(format!("{path}: not a shard-plan manifest")));
        }
        let version = root
            .get("version")
            .as_f64()
            .ok_or_else(|| PartitionError(format!("{path}: missing version")))?
            as u64;
        if version != SHARDPLAN_VERSION {
            return Err(PartitionError(format!(
                "{path}: shard-plan version {version} is not supported \
                 (this build reads version {SHARDPLAN_VERSION})"
            )));
        }
        let cfg = artifact::config_from(root.get("config")).map_err(perr)?;
        let recorded = root
            .get("config_hash")
            .as_str()
            .and_then(artifact::unhex)
            .ok_or_else(|| PartitionError(format!("{path}: bad config_hash")))?;
        if recorded != config_hash(&cfg) {
            return Err(PartitionError(format!(
                "{path}: config_hash does not match the embedded config"
            )));
        }
        if config_hash(&cfg) != config_hash(host) {
            return Err(PartitionError(format!(
                "{path}: built for config {} but the host runs {}",
                artifact::hex(config_hash(&cfg)),
                artifact::hex(config_hash(host))
            )));
        }
        let model = root
            .get("model")
            .as_str()
            .ok_or_else(|| PartitionError(format!("{path}: missing model")))?;
        let graph = parser::parse_model(model).map_err(perr)?;
        let dir = Path::new(path).parent().unwrap_or_else(|| Path::new(""));
        let entries = root
            .get("stages")
            .as_arr()
            .ok_or_else(|| PartitionError(format!("{path}: missing stages")))?;
        let mut stages = Vec::with_capacity(entries.len());
        for (k, e) in entries.iter().enumerate() {
            let start = e.get("start").as_usize();
            let end = e.get("end").as_usize();
            let file = e.get("file").as_str();
            let (Some(start), Some(end), Some(file)) = (start, end, file) else {
                return Err(PartitionError(format!("{path}: stage {k} entry is corrupt")));
            };
            // Per-stage artifact encoding, recorded since the binary
            // envelope landed. Absent in older manifests (all-JSON
            // stage sets); the actual load below sniffs the file
            // content, so this is provenance validation only.
            match e.get("format") {
                Json::Null => {}
                v => {
                    let known = v.as_str().and_then(ArtifactFormat::parse).is_some();
                    if !known {
                        return Err(PartitionError(format!(
                            "{path}: stage {k} records unknown artifact format {}",
                            v.dump()
                        )));
                    }
                }
            }
            let fp = e
                .get("fingerprint")
                .as_str()
                .and_then(artifact::unhex)
                .ok_or_else(|| PartitionError(format!("{path}: stage {k} bad fingerprint")))?;
            let apath = dir.join(file);
            let art = Artifact::load(&apath.to_string_lossy(), host).map_err(perr)?;
            if art.fingerprint() != fp {
                return Err(PartitionError(format!(
                    "{}: fingerprint {} does not match the manifest's {} — \
                     stage artifact was modified or replaced",
                    apath.to_string_lossy(),
                    artifact::hex(art.fingerprint()),
                    artifact::hex(fp)
                )));
            }
            let boundary = match e.get("boundary") {
                Json::Null => None,
                b => {
                    let (c, h, w) =
                        (b.get("c").as_usize(), b.get("h").as_usize(), b.get("w").as_usize());
                    let (Some(c), Some(h), Some(w)) = (c, h, w) else {
                        return Err(PartitionError(format!(
                            "{path}: stage {k} boundary is corrupt"
                        )));
                    };
                    Some(Boundary { c, h, w })
                }
            };
            let predicted_cycles = art.predicted_cycles();
            stages.push(Stage { start, end, artifact: art, predicted_cycles, boundary });
        }
        let plan = ShardPlan { cfg, graph, stages };
        plan.validate()?;
        Ok(plan)
    }
}

fn manifest_stem(p: &Path) -> String {
    let name = p.file_name().map(|s| s.to_string_lossy().into_owned());
    let name = name.unwrap_or_else(|| "shardplan".to_string());
    name.strip_suffix(".shardplan.json")
        .or_else(|| name.strip_suffix(".json"))
        .unwrap_or(&name)
        .to_string()
}

fn stage_file(stem: &str, k: usize, fmt: ArtifactFormat) -> String {
    format!("{stem}.stage{k}.artifact.{}", fmt.extension())
}

// ---------------------------------------------------------------------
// Cut feasibility and stage sub-graphs
// ---------------------------------------------------------------------

fn skipped(g: &Graph, opts: &CompileOptions, id: usize) -> bool {
    opts.skip_fc && matches!(g.nodes[id].kind, LayerKind::Fc { .. })
}

/// Cut positions `a` (a stage may start at node `a`) where sharding is
/// transparent: every edge crossing the cut leaves node `a-1` and lands
/// in a single-input consumer (so the consumer can read the shipped
/// activation as its network input), node `a-1` generates code (its
/// canvas is the shipped activation), and both sides keep at least one
/// code-generating node.
pub fn feasible_cuts(g: &Graph, opts: &CompileOptions) -> Vec<usize> {
    let n = g.nodes.len();
    (1..n)
        .filter(|&a| {
            for node in &g.nodes[a..] {
                for &p in &node.inputs {
                    if p < a && (p != a - 1 || node.inputs.len() != 1) {
                        return false;
                    }
                }
            }
            if skipped(g, opts, a - 1) {
                return false;
            }
            if (0..a).all(|i| skipped(g, opts, i)) || (a..n).all(|i| skipped(g, opts, i)) {
                return false;
            }
            true
        })
        .collect()
}

/// The sub-graph a stage compiles: nodes `start..end` with stage-local
/// ids; edges from node `start-1` become network-input reads, and the
/// stage input shape is node `start-1`'s output. The full range
/// (`0..n`) returns the graph verbatim, so a 1-stage partition builds
/// the identical artifact (same fingerprint) as an unsharded compile.
pub fn stage_graph(g: &Graph, start: usize, end: usize) -> Graph {
    if start == 0 && end == g.nodes.len() {
        return g.clone();
    }
    let input = if start == 0 { g.input } else { g.shapes()[start - 1] };
    let mut sg = Graph::new(&format!("{}.s{}_{}", g.name, start, end), input);
    for node in &g.nodes[start..end] {
        let inputs: Vec<usize> = if node.inputs.iter().any(|&p| p < start) {
            Vec::new()
        } else {
            node.inputs.iter().map(|&p| p - start).collect()
        };
        sg.push(node.kind.clone(), inputs, &node.name);
    }
    sg
}

/// Slice a full-model weight set down to one stage's (stage-local node
/// ids). Stage weights must come from *one* full-model
/// [`Weights::init`] — the RNG runs sequentially over the full graph,
/// so re-initializing from a stage graph would produce different
/// weights than the unsharded model.
pub fn stage_weights(full: &Weights, start: usize, end: usize) -> Weights {
    let slice = |m: &std::collections::BTreeMap<usize, crate::tensor::Tensor<f32>>| {
        m.range(start..end).map(|(&k, v)| (k - start, v.clone())).collect()
    };
    Weights { weights: slice(&full.weights), biases: slice(&full.biases) }
}

// ---------------------------------------------------------------------
// Balance objective
// ---------------------------------------------------------------------

/// Per-node predicted cycles from one full-model compile. Fused
/// conv+residual cycles land on the residual node (the lowered op's
/// `out_node`); layers the cost model does not predict (FC, avgpool)
/// contribute 0.
pub fn node_costs(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> Result<Vec<u64>, PartitionError> {
    let compiled = super::compile_impl(g, cfg, opts).map_err(perr)?;
    let mut costs = vec![0u64; g.nodes.len()];
    for lp in &compiled.plan.layers {
        costs[lp.op.out_node()] += lp.decision.predicted_cycles();
    }
    Ok(costs)
}

/// Per-stage cost sums for a cut set over precomputed node costs.
pub fn stage_costs(costs: &[u64], cuts: &[usize]) -> Vec<u64> {
    let mut out = Vec::with_capacity(cuts.len() + 1);
    let mut prev = 0usize;
    for &c in cuts.iter().chain(std::iter::once(&costs.len())) {
        out.push(costs[prev..c].iter().sum());
        prev = c;
    }
    out
}

/// (bottleneck, sum of squared stage costs): lexicographic objective.
/// The primary term bounds pipeline throughput; the secondary breaks
/// ties toward overall balance, keeping refinement deterministic.
fn score(costs: &[u64], cuts: &[usize]) -> (u64, u128) {
    let sc = stage_costs(costs, cuts);
    let max = sc.iter().copied().max().unwrap_or(0);
    let sq = sc.iter().map(|&c| (c as u128) * (c as u128)).sum();
    (max, sq)
}

fn not_enough(g: &Graph, feasible: usize, n_stages: usize) -> PartitionError {
    PartitionError(format!(
        "{} supports at most {} pipeline stages ({} feasible cuts); {} requested",
        g.name,
        feasible + 1,
        feasible,
        n_stages
    ))
}

/// The even-layer-count split snapped to feasible cuts: ideal cut `i`
/// sits at `i·n/n_stages`; each is moved to the nearest feasible
/// position that keeps the cut set strictly increasing. This is the
/// baseline [`partition`] must never lose to.
pub fn even_cuts(
    g: &Graph,
    opts: &CompileOptions,
    n_stages: usize,
) -> Result<Vec<usize>, PartitionError> {
    let n = g.nodes.len();
    if n_stages == 0 {
        return Err(PartitionError("cannot partition into 0 stages".to_string()));
    }
    let feas = feasible_cuts(g, opts);
    if feas.len() + 1 < n_stages {
        return Err(not_enough(g, feas.len(), n_stages));
    }
    let targets: Vec<f64> =
        (1..n_stages).map(|i| (i * n) as f64 / n_stages as f64).collect();
    Ok(snap(&feas, &targets, |&cut| cut as f64))
}

/// Snap ideal positions to feasible cuts: for each target in order,
/// pick the unused feasible cut closest to it (ties toward the earlier
/// cut) that still leaves enough cuts for the remaining targets.
fn snap<F: Fn(&usize) -> f64>(feas: &[usize], targets: &[f64], measure: F) -> Vec<usize> {
    let mut cuts = Vec::with_capacity(targets.len());
    let mut lo = 0usize;
    for (i, &t) in targets.iter().enumerate() {
        let hi = feas.len() - (targets.len() - 1 - i);
        let mut best = lo;
        for j in lo + 1..hi {
            if (measure(&feas[j]) - t).abs() < (measure(&feas[best]) - t).abs() {
                best = j;
            }
        }
        cuts.push(feas[best]);
        lo = best + 1;
    }
    cuts
}

/// Deterministic local-move refinement: repeatedly try moving each cut
/// to every feasible position between its neighbors, accepting strict
/// objective improvements, until a fixed point. Never worsens the seed.
fn refine(costs: &[u64], feas: &[usize], mut cuts: Vec<usize>) -> Vec<usize> {
    let n = costs.len();
    loop {
        let mut improved = false;
        for i in 0..cuts.len() {
            let lo = if i == 0 { 0 } else { cuts[i - 1] };
            let hi = if i + 1 == cuts.len() { n } else { cuts[i + 1] };
            let mut best_cut = cuts[i];
            let mut best_score = score(costs, &cuts);
            for &c in feas.iter().filter(|&&c| c > lo && c < hi && c != cuts[i]) {
                let mut cand = cuts.clone();
                cand[i] = c;
                let s = score(costs, &cand);
                if s < best_score {
                    best_score = s;
                    best_cut = c;
                }
            }
            if best_cut != cuts[i] {
                cuts[i] = best_cut;
                improved = true;
            }
        }
        if !improved {
            return cuts;
        }
    }
}

// ---------------------------------------------------------------------
// Partitioning front doors
// ---------------------------------------------------------------------

/// Partition `g` into `n_stages` balanced pipeline stages and compile
/// each. Deterministic: same inputs, same cuts, same artifacts. The
/// result's bottleneck (on the cost model's node costs) is never worse
/// than [`even_cuts`]'s, because the even split is one of the refined
/// seeds.
pub fn partition(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    n_stages: usize,
) -> Result<ShardPlan, PartitionError> {
    if n_stages == 0 {
        return Err(PartitionError("cannot partition into 0 stages".to_string()));
    }
    if n_stages == 1 {
        return partition_at(g, cfg, opts, &[]);
    }
    let feas = feasible_cuts(g, opts);
    if feas.len() + 1 < n_stages {
        return Err(not_enough(g, feas.len(), n_stages));
    }
    let costs = node_costs(g, cfg, opts)?;
    let total: u64 = costs.iter().sum();
    let prefix: Vec<u64> = std::iter::once(0)
        .chain(costs.iter().scan(0u64, |acc, &c| {
            *acc += c;
            Some(*acc)
        }))
        .collect();
    // Seed 2: cuts placed where the cost prefix crosses i/n of total.
    let targets: Vec<f64> =
        (1..n_stages).map(|i| (i as u64 * total) as f64 / n_stages as f64).collect();
    let greedy = snap(&feas, &targets, |&cut| prefix[cut] as f64);
    let even = even_cuts(g, opts, n_stages)?;
    let mut best = refine(&costs, &feas, even);
    for seed in [greedy] {
        let cand = refine(&costs, &feas, seed);
        if score(&costs, &cand) < score(&costs, &best) {
            best = cand;
        }
    }
    partition_at(g, cfg, opts, &best)
}

/// Compile the stages of an explicit cut set (must be feasible,
/// strictly increasing). `&[]` compiles the whole model as one stage —
/// bit-identical (same fingerprint) to an unsharded build.
pub fn partition_at(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    cuts: &[usize],
) -> Result<ShardPlan, PartitionError> {
    let n = g.nodes.len();
    let feas = feasible_cuts(g, opts);
    for w in cuts.windows(2) {
        if w[1] <= w[0] {
            return Err(PartitionError(format!(
                "cuts must be strictly increasing, got {cuts:?}"
            )));
        }
    }
    for &c in cuts {
        if !feas.contains(&c) {
            return Err(PartitionError(format!(
                "cut at node {c} is not feasible for {} (feasible cuts: {feas:?})",
                g.name
            )));
        }
    }
    let shapes = g.shapes();
    let compiler = Compiler::new(cfg.clone()).options(opts.clone());
    let mut bounds = vec![0usize];
    bounds.extend_from_slice(cuts);
    bounds.push(n);
    let mut stages = Vec::with_capacity(bounds.len() - 1);
    for (k, w) in bounds.windows(2).enumerate() {
        let (start, end) = (w[0], w[1]);
        let sg = stage_graph(g, start, end);
        let art = compiler.build(&sg).map_err(|e| {
            PartitionError(format!("stage {k} (nodes {start}..{end}): {e}"))
        })?;
        let last = end == n;
        if !last && art.output_node != Some(end - 1 - start) {
            return Err(PartitionError(format!(
                "stage {k} boundary node {} generates no code — cannot ship its activation",
                end - 1
            )));
        }
        if art.output_node.is_none() {
            return Err(PartitionError(format!(
                "stage {k} (nodes {start}..{end}) generates no code"
            )));
        }
        let predicted_cycles = art.predicted_cycles();
        let boundary = (!last).then(|| Boundary::of(shapes[end - 1]));
        stages.push(Stage { start, end, artifact: art, predicted_cycles, boundary });
    }
    let plan = ShardPlan { cfg: cfg.clone(), graph: g.clone(), stages };
    plan.validate()?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn opts_nofc() -> CompileOptions {
        CompileOptions { skip_fc: true, ..CompileOptions::default() }
    }

    #[test]
    fn resnet18_feasible_cuts_are_block_boundaries() {
        let g = zoo::resnet18();
        // Identity-block bypasses reach past interior cuts; only the
        // stem boundary, the four downsample-block starts and the
        // avgpool/fc tail admit transparent cuts.
        assert_eq!(feasible_cuts(&g, &CompileOptions::default()), vec![1, 8, 15, 22, 29, 30]);
        // skip_fc: the fc-only tail stage would generate no code.
        assert_eq!(feasible_cuts(&g, &opts_nofc()), vec![1, 8, 15, 22, 29]);
    }

    #[test]
    fn alexnet_feasible_cuts() {
        let g = zoo::alexnet_owt();
        assert_eq!(
            feasible_cuts(&g, &CompileOptions::default()),
            (1..=10).collect::<Vec<_>>()
        );
        // skip_fc excludes fc boundaries (9, 10) and the cut whose tail
        // stage is all-fc (8).
        assert_eq!(feasible_cuts(&g, &opts_nofc()), (1..=7).collect::<Vec<_>>());
    }

    #[test]
    fn stage_graphs_tile_the_model() {
        let g = zoo::resnet18();
        let mut covered = 0usize;
        let bounds = [0, 8, 22, g.nodes.len()];
        for w in bounds.windows(2) {
            let sg = stage_graph(&g, w[0], w[1]);
            assert_eq!(sg.nodes.len(), w[1] - w[0]);
            sg.validate().expect("stage graph must validate");
            if w[0] > 0 {
                assert_eq!(sg.input, g.shapes()[w[0] - 1]);
            }
            covered += sg.nodes.len();
        }
        assert_eq!(covered, g.nodes.len());
    }

    #[test]
    fn full_range_stage_graph_is_verbatim() {
        let g = zoo::alexnet_owt();
        let sg = stage_graph(&g, 0, g.nodes.len());
        assert_eq!(parser::dump_model(&sg), parser::dump_model(&g));
    }

    #[test]
    fn stage_weights_are_sliced_not_reinitialized() {
        let g = zoo::alexnet_owt();
        let full = Weights::init(&g, 7);
        let sw = stage_weights(&full, 2, 5);
        // conv2 (node 2) -> stage node 0; conv3 (node 4) -> stage node 2.
        assert_eq!(sw.weights[&0].data, full.weights[&2].data);
        assert_eq!(sw.weights[&2].data, full.weights[&4].data);
        assert_eq!(sw.weights.len(), 2, "pools carry no weights");
    }

    #[test]
    fn partition_balances_no_worse_than_even_split() {
        let cfg = SnowflakeConfig::default();
        let opts = opts_nofc();
        for g in [zoo::alexnet_owt(), zoo::resnet18()] {
            let costs = node_costs(&g, &cfg, &opts).unwrap();
            for n_stages in 2..=3 {
                let plan = partition(&g, &cfg, &opts, n_stages).unwrap();
                assert_eq!(plan.n_stages(), n_stages);
                plan.validate().unwrap();
                let even = even_cuts(&g, &opts, n_stages).unwrap();
                let best = stage_costs(&costs, &plan.cuts()).into_iter().max().unwrap();
                let base = stage_costs(&costs, &even).into_iter().max().unwrap();
                assert!(
                    best <= base,
                    "{} x{}: partitioner bottleneck {} worse than even split {}",
                    g.name,
                    n_stages,
                    best,
                    base
                );
            }
        }
    }

    #[test]
    fn too_many_stages_is_a_typed_error() {
        let g = zoo::resnet18();
        let cfg = SnowflakeConfig::default();
        let e = partition(&g, &cfg, &opts_nofc(), 7).unwrap_err();
        assert!(e.0.contains("at most 6 pipeline stages"), "{}", e.0);
        let e = partition(&g, &cfg, &opts_nofc(), 0).unwrap_err();
        assert!(e.0.contains("0 stages"), "{}", e.0);
    }

    #[test]
    fn infeasible_cut_is_a_typed_error() {
        let g = zoo::resnet18();
        let cfg = SnowflakeConfig::default();
        let e = partition_at(&g, &cfg, &opts_nofc(), &[3]).unwrap_err();
        assert!(e.0.contains("not feasible"), "{}", e.0);
    }

    #[test]
    fn link_cycles_model() {
        let cfg = SnowflakeConfig::default();
        // Defaults: 1 GB/s at 250 MHz = 4 bytes/cycle.
        assert_eq!(link_cycles(&cfg, 0), 0);
        assert_eq!(link_cycles(&cfg, 4000), cfg.dma_setup_cycles + 1000);
        assert_eq!(link_cycles(&cfg, 1), cfg.dma_setup_cycles + 1);
        let fast = SnowflakeConfig { link_bandwidth_gbs: 8.0, ..SnowflakeConfig::default() };
        assert_eq!(link_cycles(&fast, 4000), fast.dma_setup_cycles + 125);
    }

    #[test]
    fn manifest_roundtrip_and_tamper_detection() {
        let g = zoo::alexnet_owt();
        let cfg = SnowflakeConfig::default();
        let plan = partition(&g, &cfg, &opts_nofc(), 2).unwrap();
        let dir = std::env::temp_dir();
        let path = dir.join("repro_test_alexnet.shardplan.json");
        let path = path.to_string_lossy().into_owned();
        plan.save(&path).unwrap();
        let back = ShardPlan::load(&path, &cfg).unwrap();
        assert_eq!(back.cuts(), plan.cuts());
        assert_eq!(back.n_stages(), 2);
        for (a, b) in back.stages.iter().zip(&plan.stages) {
            assert_eq!(a.artifact.fingerprint(), b.artifact.fingerprint());
            assert_eq!(a.boundary, b.boundary);
            assert_eq!(a.predicted_cycles, b.predicted_cycles);
        }
        // Wrong host config is rejected.
        let other = SnowflakeConfig { n_cus: 2, ..SnowflakeConfig::default() };
        assert!(ShardPlan::load(&path, &other).is_err());
        // A future manifest version is rejected with a clear message.
        let text = std::fs::read_to_string(&path).unwrap();
        let bumped = text.replacen("\"version\": 1", "\"version\": 2", 1);
        let vpath = dir.join("repro_test_alexnet_v2.shardplan.json");
        std::fs::write(&vpath, bumped).unwrap();
        // Stage files resolve against the manifest dir, so the copy
        // still points at valid artifacts — only the version differs.
        let e = ShardPlan::load(&vpath.to_string_lossy(), &cfg).unwrap_err();
        assert!(e.0.contains("version 2"), "{}", e.0);
        // A swapped stage artifact is caught by the fingerprint check.
        let s0 = dir.join("repro_test_alexnet.stage0.artifact.json");
        let s1 = dir.join("repro_test_alexnet.stage1.artifact.json");
        std::fs::copy(&s1, &s0).unwrap();
        let e = ShardPlan::load(&path, &cfg).unwrap_err();
        assert!(e.0.contains("fingerprint"), "{}", e.0);
    }

    #[test]
    fn manifest_roundtrip_with_mixed_stage_formats() {
        // One stage JSON, one binary: the manifest records each format,
        // the stage files carry the matching extensions, and loading
        // sniffs both back to bit-identical artifacts.
        let g = zoo::alexnet_owt();
        let cfg = SnowflakeConfig::default();
        let plan = partition(&g, &cfg, &opts_nofc(), 2).unwrap();
        let dir = std::env::temp_dir();
        let path = dir.join("repro_test_alexnet_mixed.shardplan.json");
        let path = path.to_string_lossy().into_owned();
        plan.save_with_formats(&path, |k| {
            if k == 0 { ArtifactFormat::Json } else { ArtifactFormat::Bin }
        })
        .unwrap();
        assert!(dir.join("repro_test_alexnet_mixed.stage0.artifact.json").exists());
        assert!(dir.join("repro_test_alexnet_mixed.stage1.artifact.bin").exists());
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"format\": \"json\""), "manifest must record stage formats");
        assert!(text.contains("\"format\": \"bin\""), "manifest must record stage formats");
        let back = ShardPlan::load(&path, &cfg).unwrap();
        assert_eq!(back.cuts(), plan.cuts());
        for (a, b) in back.stages.iter().zip(&plan.stages) {
            assert_eq!(a.artifact.fingerprint(), b.artifact.fingerprint());
            assert_eq!(a.artifact.compiled.program, b.artifact.compiled.program);
        }
        // A manifest recording a format this build does not know is a
        // typed error, not a guess.
        let bad = text.replacen("\"format\": \"bin\"", "\"format\": \"zip\"", 1);
        let bpath = dir.join("repro_test_alexnet_badfmt.shardplan.json");
        // Keep the stage files resolvable: same dir, same stems.
        std::fs::write(&bpath, bad).unwrap();
        let e = ShardPlan::load(&bpath.to_string_lossy(), &cfg).unwrap_err();
        assert!(e.0.contains("unknown artifact format"), "{}", e.0);
    }
}
