//! PJRT runtime: load AOT-compiled XLA computations (HLO **text**
//! produced by `python/compile/aot.py`) and execute them natively from
//! rust — the golden numerical model (L1 Pallas kernel + L2 jax graph)
//! on the run path with Python long gone.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled artifact ready to execute on the CPU PJRT client.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// PJRT client wrapper; create once, load many artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact file and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?;
        Ok(Artifact {
            exe,
            name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned(),
        })
    }
}

impl Artifact {
    /// Execute with i16 tensors. The `xla` crate's literal API speaks
    /// int32, so the AOT exports take/return int32 and cast to the int16
    /// datapath internally; this wrapper widens/narrows losslessly.
    pub fn run_i16(&self, inputs: &[(&[i16], &[usize])]) -> Result<Vec<Vec<i16>>> {
        let widened: Vec<(Vec<i32>, &[usize])> = inputs
            .iter()
            .map(|(data, shape)| (data.iter().map(|&v| v as i32).collect(), *shape))
            .collect();
        let lits: Vec<xla::Literal> = widened
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).context("reshape input")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let tuple = result.to_tuple()?;
        tuple
            .into_iter()
            .map(|l| {
                Ok(l.to_vec::<i32>()
                    .context("read output")?
                    .into_iter()
                    .map(|v| v as i16)
                    .collect())
            })
            .collect()
    }

    /// Execute with f32 tensors.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).context("reshape input")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        tuple.into_iter().map(|l| l.to_vec::<f32>().context("read output")).collect()
    }
}

/// Default artifact directory (built by `make artifacts`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("SNOWFLAKE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
