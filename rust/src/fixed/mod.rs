//! Fixed-point arithmetic — the data type of the whole machine.
//!
//! Snowflake computes in 16-bit fixed point; the paper uses **Q8.8**
//! (8 integer bits, 8 fractional bits) for the hardware and validates a
//! **Q5.11** variant for accuracy (§5.3). This module implements generic
//! Qm.n over 16-bit storage with the exact datapath the simulator's MAC
//! units use: `i16 × i16 → i32` products, 32-bit accumulation, and a
//! rounding, saturating writeback shift.

use std::fmt;

/// A 16-bit fixed point format with `frac` fractional bits (Q(16-frac).frac).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct QFormat {
    pub frac: u32,
}

/// The paper's hardware format: Q8.8.
pub const Q8_8: QFormat = QFormat { frac: 8 };
/// The paper's higher-precision profile: Q5.11.
pub const Q5_11: QFormat = QFormat { frac: 11 };

impl fmt::Display for QFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Q{}.{}", 16 - self.frac, self.frac)
    }
}

impl QFormat {
    pub const fn new(frac: u32) -> Self {
        assert!(frac < 16);
        QFormat { frac }
    }

    /// Scale factor 2^frac.
    #[inline]
    pub fn scale(self) -> f32 {
        (1i32 << self.frac) as f32
    }

    /// Largest representable value.
    pub fn max_value(self) -> f32 {
        i16::MAX as f32 / self.scale()
    }

    /// Smallest representable value.
    pub fn min_value(self) -> f32 {
        i16::MIN as f32 / self.scale()
    }

    /// Quantize an f32 to the stored i16: round to nearest (ties away
    /// from zero), saturate to the representable range.
    #[inline]
    pub fn quantize(self, x: f32) -> i16 {
        let scaled = x * self.scale();
        let rounded = if scaled >= 0.0 { scaled + 0.5 } else { scaled - 0.5 };
        if rounded >= i16::MAX as f32 {
            i16::MAX
        } else if rounded <= i16::MIN as f32 {
            i16::MIN
        } else {
            rounded as i16
        }
    }

    /// Recover the f32 value of a stored word.
    #[inline]
    pub fn dequantize(self, q: i16) -> f32 {
        q as f32 / self.scale()
    }

    /// The MAC datapath's writeback: take a 32-bit accumulator holding a
    /// sum of `i16×i16` products (scale 2^(2·frac)), shift back to scale
    /// 2^frac with round-to-nearest, saturate to i16.
    ///
    /// This exact function is shared by the simulator ([`crate::sim`]),
    /// the reference implementation ([`crate::refimpl`]) and mirrored by
    /// the Pallas kernel (`python/compile/kernels/conv_q88.py`), so all
    /// three produce bit-identical results.
    #[inline]
    pub fn writeback(self, acc: i64) -> i16 {
        let half = 1i64 << (self.frac - 1);
        // Round to nearest, ties toward +inf (cheap in hardware: add half
        // then arithmetic shift).
        let shifted = (acc + half) >> self.frac;
        saturate_i16(shifted)
    }

    /// Quantize a whole f32 slice.
    pub fn quantize_slice(self, xs: &[f32]) -> Vec<i16> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Dequantize a whole i16 slice.
    pub fn dequantize_slice(self, qs: &[i16]) -> Vec<f32> {
        qs.iter().map(|&q| self.dequantize(q)).collect()
    }

    /// Quantization step (smallest positive representable increment).
    pub fn epsilon(self) -> f32 {
        1.0 / self.scale()
    }
}

/// Saturate a 64-bit value into i16 range.
#[inline]
pub fn saturate_i16(v: i64) -> i16 {
    if v > i16::MAX as i64 {
        i16::MAX
    } else if v < i16::MIN as i64 {
        i16::MIN
    } else {
        v as i16
    }
}

/// One multiply-accumulate step of the MAC datapath.
#[inline]
pub fn mac_step(acc: i64, a: i16, b: i16) -> i64 {
    acc + (a as i64) * (b as i64)
}

/// Saturating Q addition of two stored words (used by the residual-add
/// path: bypass values are added post-writeback in the same format).
#[inline]
pub fn sat_add(a: i16, b: i16) -> i16 {
    a.saturating_add(b)
}

/// ReLU on a stored word.
#[inline]
pub fn relu_q(a: i16) -> i16 {
    a.max(0)
}

/// Element-wise max (the pool unit's comparator).
#[inline]
pub fn max_q(a: i16, b: i16) -> i16 {
    a.max(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    #[test]
    fn q88_basics() {
        assert_eq!(Q8_8.quantize(1.0), 256);
        assert_eq!(Q8_8.quantize(-1.0), -256);
        assert_eq!(Q8_8.quantize(0.5), 128);
        assert_eq!(Q8_8.dequantize(256), 1.0);
        assert_eq!(format!("{Q8_8}"), "Q8.8");
        assert_eq!(format!("{Q5_11}"), "Q5.11");
    }

    #[test]
    fn saturation() {
        assert_eq!(Q8_8.quantize(1000.0), i16::MAX);
        assert_eq!(Q8_8.quantize(-1000.0), i16::MIN);
        assert_eq!(Q5_11.quantize(20.0), i16::MAX);
        assert_eq!(saturate_i16(1 << 40), i16::MAX);
        assert_eq!(saturate_i16(-(1 << 40)), i16::MIN);
    }

    #[test]
    fn rounding_ties() {
        // 0.001953125 = 0.5 * eps(Q8.8): rounds away from zero.
        assert_eq!(Q8_8.quantize(0.5 / 256.0), 1);
        assert_eq!(Q8_8.quantize(-0.5 / 256.0), -1);
    }

    #[test]
    fn writeback_matches_float_mac() {
        // 1.0 * 1.5 accumulated at double scale must write back as 1.5.
        let a = Q8_8.quantize(1.0);
        let b = Q8_8.quantize(1.5);
        let acc = mac_step(0, a, b);
        assert_eq!(Q8_8.writeback(acc), Q8_8.quantize(1.5));
    }

    #[test]
    fn writeback_saturates() {
        let a = Q8_8.quantize(100.0);
        let mut acc = 0i64;
        for _ in 0..100 {
            acc = mac_step(acc, a, a);
        }
        assert_eq!(Q8_8.writeback(acc), i16::MAX);
    }

    #[test]
    fn quantize_dequantize_error_bound() {
        for_cases(200, 11, |rng| {
            let fmt = if rng.bool() { Q8_8 } else { Q5_11 };
            let x = rng.f32_range(fmt.min_value(), fmt.max_value());
            let err = (fmt.dequantize(fmt.quantize(x)) - x).abs();
            assert!(err <= fmt.epsilon() * 0.5 + 1e-6, "{fmt}: x={x} err={err}");
        });
    }

    #[test]
    fn q511_finer_than_q88() {
        assert!(Q5_11.epsilon() < Q8_8.epsilon());
        assert!(Q5_11.max_value() < Q8_8.max_value());
    }

    #[test]
    fn relu_and_max() {
        assert_eq!(relu_q(-5), 0);
        assert_eq!(relu_q(5), 5);
        assert_eq!(max_q(-3, 7), 7);
        assert_eq!(sat_add(i16::MAX, 1), i16::MAX);
        assert_eq!(sat_add(i16::MIN, -1), i16::MIN);
    }

    #[test]
    fn mac_trace_matches_f64_reference() {
        // Property: MAC trace over random Q8.8 values matches an f64
        // computation within one writeback quantization step.
        for_cases(100, 5, |rng| {
            let n = rng.range(1, 64);
            let mut acc = 0i64;
            let mut reff = 0.0f64;
            for _ in 0..n {
                let a = Q8_8.quantize(rng.f32_range(-2.0, 2.0));
                let b = Q8_8.quantize(rng.f32_range(-2.0, 2.0));
                acc = mac_step(acc, a, b);
                reff += Q8_8.dequantize(a) as f64 * Q8_8.dequantize(b) as f64;
            }
            let got = Q8_8.dequantize(Q8_8.writeback(acc)) as f64;
            assert!((got - reff).abs() <= Q8_8.epsilon() as f64, "got={got} ref={reff}");
        });
    }
}
