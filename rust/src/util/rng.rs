//! Deterministic xoshiro256** PRNG.
//!
//! The offline vendor set has no `rand` crate; every stochastic piece of
//! the repository (synthetic weights, property tests, workload
//! generators) uses this generator so runs are reproducible from a seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)` (half-open, `hi > lo`).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Approximate standard normal via sum of 12 uniforms (Irwin–Hall);
    /// plenty for synthetic weight initialisation.
    pub fn normal(&mut self) -> f32 {
        let mut acc = 0.0f64;
        for _ in 0..12 {
            acc += self.f64();
        }
        (acc - 6.0) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Exponential draw with the given mean (inverse-CDF transform);
    /// the inter-arrival sampler for the Poisson / Markov-modulated
    /// load generators. `f64()` is in `[0, 1)` so the argument of `ln`
    /// stays in `(0, 1]` and the result is finite and non-negative.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -(1.0 - self.f64()).ln() * mean
    }

    /// Fill a slice with N(0, sigma) values.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.range(5, 9);
            assert!((5..9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniformity_rough() {
        // Coarse chi-square-ish sanity: 16 buckets, 64k draws.
        let mut r = Rng::new(1234);
        let mut buckets = [0u32; 16];
        for _ in 0..65_536 {
            buckets[r.below(16) as usize] += 1;
        }
        for &b in &buckets {
            // expect 4096 each; allow +-15%
            assert!((3480..=4710).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn exp_mean_and_positivity() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mut s = 0.0f64;
        for _ in 0..n {
            let x = r.exp(4.0);
            assert!(x >= 0.0 && x.is_finite());
            s += x;
        }
        let mean = s / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(77);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
