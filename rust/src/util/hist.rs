//! Fixed-bucket log-spaced histogram for latency percentiles.
//!
//! `repro serve` and `benches/serve.rs` report p50/p95/p99 queue-wait
//! and end-to-end latency per run. Keeping every sample and sorting at
//! report time would make the report cost grow with the request count;
//! instead samples land in a fixed array of log-spaced buckets
//! (8 sub-buckets per octave, ≤ ~9% relative width), recording is O(1),
//! merging is element-wise addition, and a quantile is one pass over
//! 512 counters. Bucket representatives are monotone in the bucket
//! index, so `p99 ≥ p95 ≥ p50` holds structurally — pinned by
//! `tests/serve.rs`.

/// Sub-bucket bits per octave: 2^3 = 8 sub-buckets, ≤ 2^-3 ≈ 12.5%
/// spacing (≤ ~9% worst-case representation error at bucket centers).
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;
/// 64 octaves × 8 sub-buckets: covers the whole u64 range.
const BUCKETS: usize = 64 << SUB_BITS;

/// A mergeable log-spaced histogram over `u64` samples.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    total: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: [0; BUCKETS], total: 0, max: 0 }
    }
}

/// Bucket index: octave = bit length of `v`, refined by the top
/// `SUB_BITS` bits below the leading one. Values `< SUB` map to
/// themselves (exact small-value buckets).
fn bucket(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros() as u64;
    let sub = (v >> (octave - SUB_BITS as u64)) & (SUB - 1);
    ((octave << SUB_BITS) + sub) as usize
}

/// Lower edge of a bucket — the (conservative, monotone) value a
/// quantile reports for samples in it.
fn bucket_floor(b: usize) -> u64 {
    if b < SUB as usize {
        return b as u64;
    }
    let octave = (b as u64) >> SUB_BITS;
    let sub = (b as u64) & (SUB - 1);
    (1 << octave) + (sub << (octave - SUB_BITS as u64))
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: u64) {
        self.counts[bucket(v)] += 1;
        self.total += 1;
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.max = self.max.max(other.max);
    }

    /// The exact bucket-wise merge of several histograms — what an
    /// aggregate report must be relative to its per-model parts
    /// (`ServeReport` builds its run-wide latency views this way, so
    /// aggregate quantiles come from the same samples as the per-model
    /// ones, never a second accumulation that could drift).
    pub fn merge_all<'a, I: IntoIterator<Item = &'a Histogram>>(parts: I) -> Histogram {
        let mut h = Histogram::new();
        for p in parts {
            h.merge(p);
        }
        h
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// The value at quantile `q` in `[0, 1]`: the floor of the bucket
    /// containing the `ceil(q · total)`-th sample (0 on an empty
    /// histogram, the true maximum at q = 1). Monotone in `q` by
    /// construction.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        if q >= 1.0 {
            return self.max;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // The top bucket's floor can undershoot the only value
                // in it; the tracked max is exact for q = 1.
                return bucket_floor(b).min(self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_self_indexing() {
        let mut last = 0;
        for v in [0u64, 1, 2, 7, 8, 9, 100, 1000, 65_536, 1 << 40, u64::MAX] {
            let b = bucket(v);
            assert!(b >= last || v == 0, "bucket order broke at {v}");
            last = b;
            assert!(bucket_floor(b) <= v, "floor exceeds value at {v}");
        }
        // Small values are exact.
        for v in 0..SUB {
            assert_eq!(bucket_floor(bucket(v)), v);
        }
    }

    #[test]
    fn relative_error_is_bounded() {
        for v in [100u64, 12_345, 1_000_000, 123_456_789] {
            let f = bucket_floor(bucket(v));
            assert!(f <= v && (v - f) as f64 / v as f64 <= 0.125, "{v} -> {f}");
        }
    }

    #[test]
    fn quantiles_are_ordered_and_sane() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let (p50, p95, p99) = (h.quantile(0.50), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!((400..=512).contains(&p50), "{p50}");
        assert!((850..=960).contains(&p95), "{p95}");
        assert!((900..=1000).contains(&p99), "{p99}");
        assert_eq!(h.quantile(1.0), 1000);
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for v in 0..500u64 {
            a.record(v * 7);
            all.record(v * 7);
        }
        for v in 0..300u64 {
            b.record(v * 13 + 1);
            all.record(v * 13 + 1);
        }
        a.merge(&b);
        assert_eq!(a, all);
    }

    #[test]
    fn merge_all_is_exact_bucket_wise() {
        // Three disjoint parts vs one histogram fed every sample: the
        // merged aggregate must be equal as a value (PartialEq covers
        // every bucket count, the total and the max), not just agree on
        // a few quantiles.
        let mut parts = vec![Histogram::new(), Histogram::new(), Histogram::new()];
        let mut all = Histogram::new();
        for v in 0..900u64 {
            parts[(v % 3) as usize].record(v * 11 + 3);
            all.record(v * 11 + 3);
        }
        let merged = Histogram::merge_all(parts.iter());
        assert_eq!(merged, all);
        assert_eq!(merged.count(), 900);
        // Merging nothing is the empty histogram.
        assert_eq!(Histogram::merge_all(std::iter::empty()), Histogram::new());
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert!(h.is_empty());
    }

    #[test]
    fn single_sample_is_exact_at_every_quantile() {
        let mut h = Histogram::new();
        h.record(777);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert!(h.quantile(q) <= 777);
            assert!(h.quantile(q) >= bucket_floor(bucket(777)));
        }
        assert_eq!(h.quantile(1.0), 777);
    }
}
