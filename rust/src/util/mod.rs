//! Small self-contained substrates the offline build cannot pull from
//! crates.io: a JSON parser/emitter, a deterministic PRNG, a CLI argument
//! parser, a micro-benchmark harness, a property-testing helper and a
//! fixed-bucket latency histogram.

pub mod bench;
pub mod cli;
pub mod hist;
pub mod json;
pub mod prop;
pub mod rng;
