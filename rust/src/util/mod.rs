//! Small self-contained substrates the offline build cannot pull from
//! crates.io: a JSON parser/emitter, a deterministic PRNG, a CLI argument
//! parser, a micro-benchmark harness and a property-testing helper.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
