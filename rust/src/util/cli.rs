//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Grammar: `repro <subcommand> [--flag] [--key value] [positional ...]`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--key value` options, `--flag`
/// booleans and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — `argv[0]` excluded.
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(name.to_string());
                    } else {
                        out.options.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env(flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags)
    }

    #[test]
    fn subcommand_and_positional() {
        let a = args("table1 foo bar", &[]);
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.positional, vec!["foo", "bar"]);
    }

    #[test]
    fn options_and_flags() {
        let a = args("run --model alexnet --verbose --steps 5", &["verbose"]);
        assert_eq!(a.opt("model"), Some("alexnet"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("steps", 0), 5);
    }

    #[test]
    fn equals_form() {
        let a = args("run --model=resnet18", &[]);
        assert_eq!(a.opt("model"), Some("resnet18"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = args("run --fast", &[]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn unknown_flag_before_option() {
        let a = args("run --quiet --n 3", &[]);
        assert!(a.flag("quiet"));
        assert_eq!(a.opt_usize("n", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = args("run", &[]);
        assert_eq!(a.opt_or("model", "alexnet"), "alexnet");
        assert_eq!(a.opt_f64("bw", 4.2), 4.2);
        assert_eq!(a.opt_u64("seed", 42), 42);
    }
}
