//! Minimal JSON parser and emitter.
//!
//! The offline vendor set has no `serde`/`serde_json`, and the paper's
//! compiler anyway begins with *model structure parsing* from a
//! serialized description (Torch7 files read through Thnets). Our model
//! description format is JSON; this module is the Thnets analogue.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated-but-decoded; numbers are f64 (with exact i64
//! access when integral).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so emission is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and human-readable context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer access; `None` if not a number or not integral.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= i64::MAX as f64 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Json::Null` when out of bounds.
    pub fn idx(&self, i: usize) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- emission -----------------------------------------------------------

    /// Compact single-line serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
    }

    #[test]
    fn escapes_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\te\u{1f600}".into());
        let text = s.dump();
        assert_eq!(Json::parse(&text).unwrap(), s);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors_have_offset() {
        let e = Json::parse("{\"a\" 1}").unwrap_err();
        assert!(e.offset > 0);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn dump_parse_roundtrip() {
        let v = Json::obj(vec![
            ("layers", Json::arr([Json::num(1), Json::num(2.5)])),
            ("name", Json::str("alexnet")),
            ("flag", Json::Bool(true)),
            ("none", Json::Null),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(Default::default())),
        ]);
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn integral_numbers_emit_without_fraction() {
        assert_eq!(Json::num(3).dump(), "3");
        assert_eq!(Json::num(3.25).dump(), "3.25");
    }

    #[test]
    fn accessors_miss_gracefully() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.get("missing"), &Json::Null);
        assert_eq!(v.get("a").get("deeper"), &Json::Null);
        assert_eq!(v.idx(0), &Json::Null);
        assert_eq!(Json::Num(1.5).as_i64(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
