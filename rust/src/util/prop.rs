//! Property-testing helper (no `proptest` in the offline vendor set).
//!
//! `for_cases(n, seed, f)` runs `f` against `n` independently seeded RNGs
//! and, on panic, reports the failing case index and seed so the case can
//! be replayed with `replay(seed, case, f)`.

use crate::util::rng::Rng;

/// Run `cases` randomized cases. Each case gets a fresh `Rng` derived
/// from (`seed`, case index). Panics propagate with case context.
pub fn for_cases<F: FnMut(&mut Rng) + std::panic::UnwindSafe + Copy>(
    cases: usize,
    seed: u64,
    f: F,
) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let result = std::panic::catch_unwind(move || {
            let mut rng = Rng::new(case_seed);
            let mut f = f;
            f(&mut rng);
        });
        if let Err(payload) = result {
            eprintln!(
                "property failed at case {case}/{cases} (seed={seed}, case_seed={case_seed}); \
                 replay with util::prop::replay({seed}, {case}, ..)"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Re-run a single failing case from `for_cases`.
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, case: usize, mut f: F) {
    let case_seed = seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut rng = Rng::new(case_seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        for_cases(50, 1, |rng| {
            let a = rng.range(0, 100);
            let b = rng.range(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failing_case() {
        let result = std::panic::catch_unwind(|| {
            for_cases(50, 2, |rng| {
                // Fails eventually: asserts value != a particular residue.
                assert_ne!(rng.below(7), 3);
            });
        });
        assert!(result.is_err());
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        replay(9, 4, |rng| seen.push(rng.next_u64()));
        let mut seen2 = Vec::new();
        replay(9, 4, |rng| seen2.push(rng.next_u64()));
        assert_eq!(seen, seen2);
    }
}
