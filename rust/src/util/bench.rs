//! Micro-benchmark harness (no `criterion` in the offline vendor set).
//!
//! Design: warmup iterations, then timed samples; report min / median /
//! mean / p95 wall-clock. Benches in `rust/benches/*.rs` use
//! `harness = false` and drive this directly, printing both the timing
//! lines and the paper-table rows they regenerate.

use std::time::{Duration, Instant};

/// One benchmark measurement summary.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub samples: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl Summary {
    pub fn print(&self) {
        println!(
            "bench {:<40} samples={:<3} min={:>10?} median={:>10?} mean={:>10?} p95={:>10?}",
            self.name, self.samples, self.min, self.median, self.mean, self.p95
        );
    }
}

/// Benchmark runner with configurable warmup/sample counts.
pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
    /// Soft wall-clock cap for the whole measurement of one bench.
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, samples: 10, max_total: Duration::from_secs(20) }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, samples: 5, max_total: Duration::from_secs(10) }
    }

    /// Time `f` and return the summary (also printed).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let start_all = Instant::now();
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
            if start_all.elapsed() > self.max_total && times.len() >= 3 {
                break;
            }
        }
        times.sort();
        let n = times.len();
        let total: Duration = times.iter().sum();
        let summary = Summary {
            name: name.to_string(),
            samples: n,
            min: times[0],
            median: times[n / 2],
            mean: total / n as u32,
            p95: times[(n * 95 / 100).min(n - 1)],
        };
        summary.print();
        summary
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bencher { warmup: 1, samples: 4, max_total: Duration::from_secs(5) };
        let mut count = 0usize;
        let s = b.run("noop", || {
            count += 1;
            black_box(count);
        });
        assert_eq!(count, 5); // 1 warmup + 4 samples
        assert_eq!(s.samples, 4);
        assert!(s.min <= s.median && s.median <= s.p95);
    }

    #[test]
    fn respects_time_cap() {
        let b = Bencher {
            warmup: 0,
            samples: 1000,
            max_total: Duration::from_millis(50),
        };
        let s = b.run("sleepy", || std::thread::sleep(Duration::from_millis(20)));
        assert!(s.samples < 1000);
        assert!(s.samples >= 3);
    }
}
