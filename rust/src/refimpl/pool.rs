//! Max / average pooling references.
//!
//! Max pooling maps to the hardware MAX instruction (element-wise
//! comparison with a retained vector). Average pooling is implemented —
//! exactly as §2 prescribes — as a CONV with a single weight value of
//! 1/window, so the fixed-point path reuses the MAC datapath and
//! reproduces the same rounding the hardware would.

use crate::fixed::{mac_step, max_q, QFormat};
use crate::model::layer::conv_out;
use crate::tensor::Tensor;

/// fp32 max pooling with zero padding (padded cells use -inf so they
/// never win; matches Torch7 semantics for positive-padded pooling).
pub fn maxpool_f32(input: &Tensor<f32>, kh: usize, kw: usize, stride: usize, pad: usize) -> Tensor<f32> {
    let (c, hi, wi) = (input.shape[0], input.shape[1], input.shape[2]);
    let ho = conv_out(hi, kh, stride, pad);
    let wo = conv_out(wi, kw, stride, pad);
    let mut out = Tensor::zeros(&[c, ho, wo]);
    for ch in 0..c {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut m = f32::NEG_INFINITY;
                for fy in 0..kh {
                    let iy = (oy * stride + fy) as isize - pad as isize;
                    if iy < 0 || iy >= hi as isize {
                        continue;
                    }
                    for fx in 0..kw {
                        let ix = (ox * stride + fx) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        m = m.max(input.at3(ch, iy as usize, ix as usize));
                    }
                }
                out.set3(ch, oy, ox, m);
            }
        }
    }
    out
}

/// Fixed-point max pooling (the MAX instruction's retained-vector compare).
pub fn maxpool_q(input: &Tensor<i16>, kh: usize, kw: usize, stride: usize, pad: usize) -> Tensor<i16> {
    let (c, hi, wi) = (input.shape[0], input.shape[1], input.shape[2]);
    let ho = conv_out(hi, kh, stride, pad);
    let wo = conv_out(wi, kw, stride, pad);
    let mut out = Tensor::zeros(&[c, ho, wo]);
    for ch in 0..c {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut m = i16::MIN;
                for fy in 0..kh {
                    let iy = (oy * stride + fy) as isize - pad as isize;
                    if iy < 0 || iy >= hi as isize {
                        continue;
                    }
                    for fx in 0..kw {
                        let ix = (ox * stride + fx) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        m = max_q(m, input.at3(ch, iy as usize, ix as usize));
                    }
                }
                out.set3(ch, oy, ox, m);
            }
        }
    }
    out
}

/// fp32 average pooling (window mean, zero-padded cells count in the
/// divisor — conv-with-constant-weight semantics, as the hardware does it).
pub fn avgpool_f32(input: &Tensor<f32>, kh: usize, kw: usize, stride: usize, pad: usize) -> Tensor<f32> {
    let (c, hi, wi) = (input.shape[0], input.shape[1], input.shape[2]);
    let ho = conv_out(hi, kh, stride, pad);
    let wo = conv_out(wi, kw, stride, pad);
    let inv = 1.0 / (kh * kw) as f32;
    let mut out = Tensor::zeros(&[c, ho, wo]);
    for ch in 0..c {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = 0.0;
                for fy in 0..kh {
                    let iy = (oy * stride + fy) as isize - pad as isize;
                    if iy < 0 || iy >= hi as isize {
                        continue;
                    }
                    for fx in 0..kw {
                        let ix = (ox * stride + fx) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        acc += input.at3(ch, iy as usize, ix as usize);
                    }
                }
                out.set3(ch, oy, ox, acc * inv);
            }
        }
    }
    out
}

/// Fixed-point average pooling as a MAC trace with the quantized 1/window
/// weight — bit-exact with what the compiled CONV does on hardware.
pub fn avgpool_q(
    input: &Tensor<i16>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    fmt: QFormat,
) -> Tensor<i16> {
    let (c, hi, wi) = (input.shape[0], input.shape[1], input.shape[2]);
    let ho = conv_out(hi, kh, stride, pad);
    let wo = conv_out(wi, kw, stride, pad);
    let inv_w = fmt.quantize(1.0 / (kh * kw) as f32);
    let mut out = Tensor::zeros(&[c, ho, wo]);
    for ch in 0..c {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = 0i64;
                for fy in 0..kh {
                    let iy = (oy * stride + fy) as isize - pad as isize;
                    if iy < 0 || iy >= hi as isize {
                        continue;
                    }
                    for fx in 0..kw {
                        let ix = (ox * stride + fx) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        acc = mac_step(acc, input.at3(ch, iy as usize, ix as usize), inv_w);
                    }
                }
                out.set3(ch, oy, ox, fmt.writeback(acc));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q8_8;
    use crate::util::prop::for_cases;
    use crate::util::rng::Rng;

    fn rand_t3(rng: &mut Rng, c: usize, h: usize, w: usize) -> Tensor<f32> {
        let mut t = Tensor::zeros(&[c, h, w]);
        for v in t.data.iter_mut() {
            *v = rng.f32_range(-2.0, 2.0);
        }
        t
    }

    #[test]
    fn maxpool_known() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 5.0, -3.0, 2.0]);
        let y = maxpool_f32(&x, 2, 2, 2, 0);
        assert_eq!(y.data, vec![5.0]);
    }

    #[test]
    fn maxpool_3x3_stride2_shape() {
        let x: Tensor<f32> = Tensor::zeros(&[64, 55, 55]);
        let y = maxpool_f32(&x, 3, 3, 2, 0);
        assert_eq!(y.shape, vec![64, 27, 27]);
    }

    #[test]
    fn maxpool_q_matches_f32() {
        for_cases(30, 31, |rng| {
            let (c, h, w) = (rng.range(1, 4), rng.range(4, 9), rng.range(4, 9));
            let x = rand_t3(rng, c, h, w);
            let stride = rng.range(1, 3);
            let pad = rng.range(0, 2);
            if h + 2 * pad < 3 || w + 2 * pad < 3 {
                return;
            }
            let yf = maxpool_f32(&x, 3, 3, stride, pad);
            let yq = maxpool_q(&x.quantize(Q8_8), 3, 3, stride, pad);
            // Max commutes with monotone quantization.
            assert_eq!(yq.data, yf.quantize(Q8_8).data);
        });
    }

    #[test]
    fn avgpool_known() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let y = avgpool_f32(&x, 2, 2, 2, 0);
        assert_eq!(y.data, vec![2.5]);
    }

    #[test]
    fn avgpool_q_tracks_f32() {
        for_cases(30, 33, |rng| {
            let (c, h, w) = (rng.range(1, 4), rng.range(7, 10), rng.range(7, 10));
            let x = rand_t3(rng, c, h, w);
            let yf = avgpool_f32(&x, 7, 7, 1, 0);
            let yq = avgpool_q(&x.quantize(Q8_8), 7, 7, 1, 0, Q8_8).dequantize(Q8_8);
            // 49 taps of eps-level noise.
            let tol = Q8_8.epsilon() * 8.0;
            assert!(yf.max_abs_diff(&yq) <= tol, "{}", yf.max_abs_diff(&yq));
        });
    }

    #[test]
    fn maxpool_padding_never_wins() {
        // All-negative input with padding: padded cells are skipped, so
        // outputs stay negative (not clamped to 0).
        let x = Tensor::from_vec(&[1, 2, 2], vec![-1.0f32; 4]);
        let y = maxpool_f32(&x, 3, 3, 2, 1);
        assert!(y.data.iter().all(|&v| v == -1.0));
        let yq = maxpool_q(&x.quantize(Q8_8), 3, 3, 2, 1);
        assert!(yq.data.iter().all(|&v| v == Q8_8.quantize(-1.0)));
    }
}
