//! Whole-graph forward passes (layer-by-layer validation, §5.3).

use super::{conv, fc, pool};
use crate::fixed::QFormat;
use crate::model::graph::Graph;
use crate::model::layer::LayerKind;
use crate::model::weights::Weights;
use crate::tensor::Tensor;

/// fp32 forward pass; returns every node's output in node order.
pub fn forward_f32(g: &Graph, w: &Weights, input: &Tensor<f32>) -> Vec<Tensor<f32>> {
    let mut outs: Vec<Tensor<f32>> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let x = match node.inputs.first() {
            None => input,
            Some(&p) => &outs[p],
        };
        let y = match &node.kind {
            LayerKind::Conv { stride, pad, relu, .. } => conv::conv_f32(
                x,
                w.weight(node.id),
                w.bias(node.id),
                *stride,
                *pad,
                *relu,
                None,
            ),
            LayerKind::MaxPool { kh, kw, stride, pad } => pool::maxpool_f32(x, *kh, *kw, *stride, *pad),
            LayerKind::AvgPool { kh, kw, stride, pad } => pool::avgpool_f32(x, *kh, *kw, *stride, *pad),
            LayerKind::Fc { relu, .. } => {
                let flat = Tensor::from_vec(&[x.len(), 1, 1], x.data.clone());
                fc::fc_f32(&flat, w.weight(node.id), w.bias(node.id), *relu)
            }
            LayerKind::ResidualAdd { relu } => {
                conv::residual_f32(&outs[node.inputs[0]], &outs[node.inputs[1]], *relu)
            }
            LayerKind::Relu => Tensor {
                shape: x.shape.clone(),
                data: x.data.iter().map(|v| v.max(0.0)).collect(),
            },
        };
        outs.push(y);
    }
    outs
}

/// Fixed-point forward pass in format `fmt`; weights/input quantized on
/// entry, every intermediate stays in i16 (exactly what the hardware
/// keeps in DRAM between layers).
pub fn forward_q(g: &Graph, w: &Weights, input: &Tensor<f32>, fmt: QFormat) -> Vec<Tensor<i16>> {
    let xq = input.quantize(fmt);
    let mut outs: Vec<Tensor<i16>> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let x = match node.inputs.first() {
            None => &xq,
            Some(&p) => &outs[p],
        };
        let y = match &node.kind {
            LayerKind::Conv { stride, pad, relu, .. } => conv::conv_q(
                x,
                &w.weight(node.id).quantize(fmt),
                &w.bias(node.id).quantize(fmt),
                *stride,
                *pad,
                *relu,
                None,
                fmt,
            ),
            LayerKind::MaxPool { kh, kw, stride, pad } => pool::maxpool_q(x, *kh, *kw, *stride, *pad),
            LayerKind::AvgPool { kh, kw, stride, pad } => {
                pool::avgpool_q(x, *kh, *kw, *stride, *pad, fmt)
            }
            LayerKind::Fc { relu, .. } => {
                let flat = Tensor::from_vec(&[x.len(), 1, 1], x.data.clone());
                fc::fc_q(
                    &flat,
                    &w.weight(node.id).quantize(fmt),
                    &w.bias(node.id).quantize(fmt),
                    *relu,
                    fmt,
                )
            }
            LayerKind::ResidualAdd { relu } => {
                conv::residual_q(&outs[node.inputs[0]], &outs[node.inputs[1]], *relu)
            }
            LayerKind::Relu => Tensor {
                shape: x.shape.clone(),
                data: x.data.iter().map(|&v| v.max(0)).collect(),
            },
        };
        outs.push(y);
    }
    outs
}

/// Single node output (fp32), given already-computed producer outputs.
pub fn node_output_f32(g: &Graph, w: &Weights, input: &Tensor<f32>, node: usize) -> Tensor<f32> {
    forward_f32(g, w, input).swap_remove(node)
}

/// Single node output (fixed point).
pub fn node_output_q(
    g: &Graph,
    w: &Weights,
    input: &Tensor<f32>,
    node: usize,
    fmt: QFormat,
) -> Tensor<i16> {
    forward_q(g, w, input, fmt).swap_remove(node)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q5_11, Q8_8};
    use crate::model::weights::synthetic_input;
    use crate::model::zoo;
    use crate::model::layer::Shape;

    fn tiny_net() -> Graph {
        let mut g = Graph::new("tiny", Shape::new(3, 16, 16));
        let c1 = g.push_seq(
            LayerKind::Conv { in_ch: 3, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c1",
        );
        let p = g.push(LayerKind::MaxPool { kh: 2, kw: 2, stride: 2, pad: 0 }, vec![c1], "p");
        let c2 = g.push(
            LayerKind::Conv { in_ch: 8, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: false },
            vec![p],
            "c2",
        );
        let add = g.push(LayerKind::ResidualAdd { relu: true }, vec![c2, p], "add");
        let ap = g.push(LayerKind::AvgPool { kh: 8, kw: 8, stride: 1, pad: 0 }, vec![add], "ap");
        g.push(LayerKind::Fc { in_features: 8, out_features: 4, relu: false }, vec![ap], "fc");
        g.validate().unwrap();
        g
    }

    #[test]
    fn shapes_agree_with_graph_inference() {
        let g = tiny_net();
        let w = Weights::init(&g, 5);
        let x = synthetic_input(&g, 5);
        let outs = forward_f32(&g, &w, &x);
        for (o, s) in outs.iter().zip(g.shapes()) {
            assert_eq!(o.shape, vec![s.c, s.h, s.w]);
        }
    }

    #[test]
    fn q_tracks_f32_through_whole_net() {
        let g = tiny_net();
        let w = Weights::init(&g, 5);
        let x = synthetic_input(&g, 5);
        let yf = forward_f32(&g, &w, &x);
        let yq = forward_q(&g, &w, &x, Q8_8);
        let last_f = yf.last().unwrap();
        let last_q = yq.last().unwrap().dequantize(Q8_8);
        // Error accumulates across layers; just require closeness.
        assert!(last_f.max_abs_diff(&last_q) < 0.25, "{}", last_f.max_abs_diff(&last_q));
    }

    #[test]
    fn q511_is_more_accurate_than_q88() {
        let g = tiny_net();
        let w = Weights::init(&g, 6);
        let x = synthetic_input(&g, 6);
        let yf = forward_f32(&g, &w, &x);
        let e88 = yf
            .last()
            .unwrap()
            .max_abs_diff(&forward_q(&g, &w, &x, Q8_8).last().unwrap().dequantize(Q8_8));
        let e511 = yf
            .last()
            .unwrap()
            .max_abs_diff(&forward_q(&g, &w, &x, Q5_11).last().unwrap().dequantize(Q5_11));
        assert!(e511 < e88, "Q5.11 err {e511} !< Q8.8 err {e88}");
    }

    #[test]
    fn alexnet_first_layers_run() {
        // Truncated AlexNet (first 4 nodes) to keep test time sane.
        let full = zoo::alexnet_owt();
        let mut g = Graph::new("alexnet_head", full.input);
        for node in &full.nodes[..4] {
            g.push(node.kind.clone(), node.inputs.clone(), &node.name);
        }
        let w = Weights::init(&g, 9);
        let x = synthetic_input(&g, 9);
        let outs = forward_q(&g, &w, &x, Q8_8);
        assert_eq!(outs.last().unwrap().shape, vec![192, 13, 13]);
        // Non-degenerate output.
        let nonzero = outs.last().unwrap().data.iter().filter(|&&v| v != 0).count();
        assert!(nonzero > 1000);
    }
}
