//! Fully connected layer references.
//!
//! §2: an FC layer is "a data movement intensive operation … memory
//! bandwidth is a bottleneck". On Snowflake it executes as a 1×1 CONV
//! over a flattened 1×1 map — the paper's uniform *trace* representation
//! — so the fixed-point path here is a single long MAC trace per output
//! feature with the standard writeback.

use crate::fixed::{mac_step, relu_q, QFormat};
use crate::tensor::Tensor;

/// fp32 FC: `weight` is [out, in, 1, 1] (KCHW like conv), input is any
/// shape with `numel == in`.
pub fn fc_f32(input: &Tensor<f32>, weight: &Tensor<f32>, bias: &Tensor<f32>, relu: bool) -> Tensor<f32> {
    let out_f = weight.shape[0];
    let in_f = weight.shape[1];
    assert_eq!(input.len(), in_f, "fc input numel mismatch");
    assert_eq!(bias.len(), out_f);
    let mut out = Tensor::zeros(&[out_f, 1, 1]);
    for o in 0..out_f {
        let row = &weight.data[o * in_f..(o + 1) * in_f];
        let mut acc = bias.data[o];
        for (x, w) in input.data.iter().zip(row) {
            acc += x * w;
        }
        if relu {
            acc = acc.max(0.0);
        }
        out.data[o] = acc;
    }
    out
}

/// Fixed-point FC with the MAC datapath.
pub fn fc_q(
    input: &Tensor<i16>,
    weight: &Tensor<i16>,
    bias: &Tensor<i16>,
    relu: bool,
    fmt: QFormat,
) -> Tensor<i16> {
    let out_f = weight.shape[0];
    let in_f = weight.shape[1];
    assert_eq!(input.len(), in_f, "fc input numel mismatch");
    assert_eq!(bias.len(), out_f);
    let mut out = Tensor::zeros(&[out_f, 1, 1]);
    for o in 0..out_f {
        let row = &weight.data[o * in_f..(o + 1) * in_f];
        let mut acc = (bias.data[o] as i64) << fmt.frac;
        for (&x, &w) in input.data.iter().zip(row) {
            acc = mac_step(acc, x, w);
        }
        let mut v = fmt.writeback(acc);
        if relu {
            v = relu_q(v);
        }
        out.data[o] = v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q8_8;
    use crate::util::prop::for_cases;
    use crate::util::rng::Rng;

    #[test]
    fn known_dot_product() {
        let x = Tensor::from_vec(&[3, 1, 1], vec![1.0, 2.0, 3.0]);
        let w = Tensor::from_vec(&[2, 3, 1, 1], vec![1.0, 0.0, 0.0, 0.5, 0.5, 0.5]);
        let b = Tensor::from_vec(&[2], vec![0.0, 1.0]);
        let y = fc_f32(&x, &w, &b, false);
        assert_eq!(y.data, vec![1.0, 4.0]);
    }

    #[test]
    fn relu_applies() {
        let x = Tensor::from_vec(&[1, 1, 1], vec![1.0]);
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![-1.0]);
        let b = Tensor::from_vec(&[1], vec![0.0]);
        assert_eq!(fc_f32(&x, &w, &b, true).data[0], 0.0);
        let yq = fc_q(&x.quantize(Q8_8), &w.quantize(Q8_8), &b.quantize(Q8_8), true, Q8_8);
        assert_eq!(yq.data[0], 0);
    }

    #[test]
    fn q_matches_f32_within_noise() {
        for_cases(30, 41, |rng| {
            let in_f = rng.range(4, 128);
            let out_f = rng.range(1, 16);
            let mut x = Tensor::zeros(&[in_f, 1, 1]);
            let mut rngc = rng.clone();
            for v in x.data.iter_mut() {
                *v = rngc.f32_range(-1.0, 1.0);
            }
            let mut w = Tensor::zeros(&[out_f, in_f, 1, 1]);
            for v in w.data.iter_mut() {
                *v = rngc.f32_range(-0.2, 0.2);
            }
            let mut b = Tensor::zeros(&[out_f]);
            for v in b.data.iter_mut() {
                *v = rngc.f32_range(-0.5, 0.5);
            }
            let yf = fc_f32(&x, &w, &b, false);
            let yq = fc_q(&x.quantize(Q8_8), &w.quantize(Q8_8), &b.quantize(Q8_8), false, Q8_8)
                .dequantize(Q8_8);
            let tol = Q8_8.epsilon() * ((in_f as f32).sqrt() * 2.0 + 2.0);
            assert!(yf.max_abs_diff(&yq) <= tol);
        });
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let x = Tensor::from_vec(&[2, 1, 1], vec![1.0, 2.0]);
        let w = Tensor::from_vec(&[1, 3, 1, 1], vec![1.0, 1.0, 1.0]);
        let b = Tensor::from_vec(&[1], vec![0.0]);
        fc_f32(&x, &w, &b, false);
    }
}
