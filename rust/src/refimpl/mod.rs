//! Reference layer implementations.
//!
//! §5.3: "for validation purposes, we wrote a software implementation of
//! the model's layers using Q8.8 to simulate Snowflake's compute
//! operations. Result checking allows layer by layer validation." This
//! module is that software implementation, in two flavours:
//!
//! * fp32 — numerical ground truth (and the fp32 row of the accuracy
//!   experiment);
//! * Qm.n fixed point — bit-exact model of the Snowflake MAC datapath
//!   ([`crate::fixed`]), used to validate the simulator's outputs word
//!   by word and mirrored by the Pallas kernel on the python side.

pub mod conv;
pub mod fc;
pub mod forward;
pub mod pool;

pub use forward::{forward_f32, forward_q, node_output_f32, node_output_q};
