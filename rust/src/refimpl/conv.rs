//! Spatial convolution references (fp32 and fixed point).

use crate::fixed::{mac_step, relu_q, sat_add, QFormat};
use crate::model::layer::conv_out;
use crate::tensor::Tensor;

/// fp32 convolution, CHW input, KCHW weights, zero padding.
/// `bypass` (same shape as output) is added before the optional ReLU —
/// the fused residual path.
#[allow(clippy::too_many_arguments)]
pub fn conv_f32(
    input: &Tensor<f32>,
    weight: &Tensor<f32>,
    bias: &Tensor<f32>,
    stride: usize,
    pad: usize,
    relu: bool,
    bypass: Option<&Tensor<f32>>,
) -> Tensor<f32> {
    let (ci, hi, wi) = (input.shape[0], input.shape[1], input.shape[2]);
    let (k, ck, kh, kw) = (weight.shape[0], weight.shape[1], weight.shape[2], weight.shape[3]);
    assert_eq!(ci, ck, "channel mismatch");
    assert_eq!(bias.len(), k);
    let ho = conv_out(hi, kh, stride, pad);
    let wo = conv_out(wi, kw, stride, pad);
    if let Some(bp) = bypass {
        assert_eq!(bp.shape, vec![k, ho, wo]);
    }
    let mut out = Tensor::zeros(&[k, ho, wo]);
    for ko in 0..k {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = bias.data[ko];
                for c in 0..ci {
                    for fy in 0..kh {
                        let iy = (oy * stride + fy) as isize - pad as isize;
                        if iy < 0 || iy >= hi as isize {
                            continue;
                        }
                        for fx in 0..kw {
                            let ix = (ox * stride + fx) as isize - pad as isize;
                            if ix < 0 || ix >= wi as isize {
                                continue;
                            }
                            acc += input.at3(c, iy as usize, ix as usize)
                                * weight.at4(ko, c, fy, fx);
                        }
                    }
                }
                if let Some(bp) = bypass {
                    acc += bp.at3(ko, oy, ox);
                }
                if relu {
                    acc = acc.max(0.0);
                }
                out.set3(ko, oy, ox, acc);
            }
        }
    }
    out
}

/// Fixed-point convolution with the exact Snowflake MAC datapath:
/// i16×i16 products accumulated in 64-bit at scale 2^(2·frac), bias
/// pre-loaded into the accumulator at the same scale, rounding
/// saturating writeback, then bypass add (saturating, post-writeback)
/// and ReLU — the order the hardware applies them (§4 VMOV/MAC).
#[allow(clippy::too_many_arguments)]
pub fn conv_q(
    input: &Tensor<i16>,
    weight: &Tensor<i16>,
    bias: &Tensor<i16>,
    stride: usize,
    pad: usize,
    relu: bool,
    bypass: Option<&Tensor<i16>>,
    fmt: QFormat,
) -> Tensor<i16> {
    let (ci, hi, wi) = (input.shape[0], input.shape[1], input.shape[2]);
    let (k, ck, kh, kw) = (weight.shape[0], weight.shape[1], weight.shape[2], weight.shape[3]);
    assert_eq!(ci, ck, "channel mismatch");
    assert_eq!(bias.len(), k);
    let ho = conv_out(hi, kh, stride, pad);
    let wo = conv_out(wi, kw, stride, pad);
    if let Some(bp) = bypass {
        assert_eq!(bp.shape, vec![k, ho, wo]);
    }
    let mut out = Tensor::zeros(&[k, ho, wo]);
    for ko in 0..k {
        // Bias enters the accumulator pre-shifted to product scale —
        // exactly what VMOV-ing the bias into the MAC accumulator does.
        let bias_acc = (bias.data[ko] as i64) << fmt.frac;
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = bias_acc;
                for c in 0..ci {
                    for fy in 0..kh {
                        let iy = (oy * stride + fy) as isize - pad as isize;
                        if iy < 0 || iy >= hi as isize {
                            continue;
                        }
                        for fx in 0..kw {
                            let ix = (ox * stride + fx) as isize - pad as isize;
                            if ix < 0 || ix >= wi as isize {
                                continue;
                            }
                            acc = mac_step(
                                acc,
                                input.at3(c, iy as usize, ix as usize),
                                weight.at4(ko, c, fy, fx),
                            );
                        }
                    }
                }
                let mut v = fmt.writeback(acc);
                if let Some(bp) = bypass {
                    v = sat_add(v, bp.at3(ko, oy, ox));
                }
                if relu {
                    v = relu_q(v);
                }
                out.set3(ko, oy, ox, v);
            }
        }
    }
    out
}

/// Element-wise residual add (standalone node form).
pub fn residual_q(a: &Tensor<i16>, b: &Tensor<i16>, relu: bool) -> Tensor<i16> {
    assert_eq!(a.shape, b.shape);
    let data = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| {
            let v = sat_add(x, y);
            if relu {
                relu_q(v)
            } else {
                v
            }
        })
        .collect();
    Tensor { shape: a.shape.clone(), data }
}

/// fp32 residual add.
pub fn residual_f32(a: &Tensor<f32>, b: &Tensor<f32>, relu: bool) -> Tensor<f32> {
    assert_eq!(a.shape, b.shape);
    let data = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| {
            let v = x + y;
            if relu {
                v.max(0.0)
            } else {
                v
            }
        })
        .collect();
    Tensor { shape: a.shape.clone(), data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q8_8;
    use crate::util::prop::for_cases;
    use crate::util::rng::Rng;

    fn rand_t3(rng: &mut Rng, c: usize, h: usize, w: usize, amp: f32) -> Tensor<f32> {
        let mut t = Tensor::zeros(&[c, h, w]);
        for v in t.data.iter_mut() {
            *v = rng.f32_range(-amp, amp);
        }
        t
    }

    fn rand_t4(rng: &mut Rng, k: usize, c: usize, kh: usize, kw: usize, amp: f32) -> Tensor<f32> {
        let mut t = Tensor::zeros(&[k, c, kh, kw]);
        for v in t.data.iter_mut() {
            *v = rng.f32_range(-amp, amp);
        }
        t
    }

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 conv with identity weights returns the input (fp32).
        let mut rng = Rng::new(1);
        let x = rand_t3(&mut rng, 3, 4, 4, 1.0);
        let mut w = Tensor::zeros(&[3, 3, 1, 1]);
        for c in 0..3 {
            w.set4(c, c, 0, 0, 1.0);
        }
        let b = Tensor::zeros(&[3]);
        let y = conv_f32(&x, &w, &b, 1, 0, false, None);
        assert!(x.max_abs_diff(&y) < 1e-6);
    }

    #[test]
    fn known_3x3_sum() {
        // All-ones 3x3 kernel over all-ones 1-channel input, no pad:
        // every interior output = 9.
        let x = Tensor::from_vec(&[1, 4, 4], vec![1.0; 16]);
        let w = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        let b = Tensor::from_vec(&[1], vec![0.0]);
        let y = conv_f32(&x, &w, &b, 1, 0, false, None);
        assert_eq!(y.shape, vec![1, 2, 2]);
        assert!(y.data.iter().all(|&v| (v - 9.0).abs() < 1e-6));
    }

    #[test]
    fn padding_zeros_edges() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0; 4]);
        let w = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        let b = Tensor::from_vec(&[1], vec![0.0]);
        let y = conv_f32(&x, &w, &b, 1, 1, false, None);
        assert_eq!(y.shape, vec![1, 2, 2]);
        // Corner sees 4 ones.
        assert!((y.at3(0, 0, 0) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn stride_subsamples() {
        let x = Tensor::from_vec(&[1, 5, 5], (0..25).map(|i| i as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let b = Tensor::from_vec(&[1], vec![0.0]);
        let y = conv_f32(&x, &w, &b, 2, 0, false, None);
        assert_eq!(y.shape, vec![1, 3, 3]);
        assert_eq!(y.at3(0, 1, 1), 12.0);
    }

    #[test]
    fn relu_clamps() {
        let x = Tensor::from_vec(&[1, 1, 1], vec![1.0]);
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![-2.0]);
        let b = Tensor::from_vec(&[1], vec![0.0]);
        let y = conv_f32(&x, &w, &b, 1, 0, true, None);
        assert_eq!(y.data[0], 0.0);
    }

    #[test]
    fn q_conv_tracks_f32_within_quantization_noise() {
        for_cases(25, 21, |rng| {
            let (c, h, w) = (rng.range(1, 5), rng.range(3, 8), rng.range(3, 8));
            let k = rng.range(1, 5);
            let ks = *[1usize, 3].get(rng.range(0, 2)).unwrap();
            let stride = rng.range(1, 3);
            let pad = rng.range(0, ks / 2 + 1);
            if h + 2 * pad < ks || w + 2 * pad < ks {
                return;
            }
            let x = rand_t3(rng, c, h, w, 1.0);
            let wt = rand_t4(rng, k, c, ks, ks, 0.3);
            let mut b = Tensor::zeros(&[k]);
            for v in b.data.iter_mut() {
                *v = rng.f32_range(-0.2, 0.2);
            }
            let yf = conv_f32(&x, &wt, &b, stride, pad, true, None);
            let yq = conv_q(
                &x.quantize(Q8_8),
                &wt.quantize(Q8_8),
                &b.quantize(Q8_8),
                stride,
                pad,
                true,
                None,
                Q8_8,
            );
            let yq_f = yq.dequantize(Q8_8);
            // Error budget: per-term quantization noise ~ eps * sqrt(taps).
            let taps = (c * ks * ks) as f32;
            let tol = Q8_8.epsilon() * (taps.sqrt() * 2.0 + 2.0);
            assert!(
                yf.max_abs_diff(&yq_f) <= tol,
                "diff {} > tol {tol}",
                yf.max_abs_diff(&yq_f)
            );
        });
    }

    #[test]
    fn bypass_applied_after_writeback() {
        let x = Tensor::from_vec(&[1, 1, 1], vec![1.0]);
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let b = Tensor::from_vec(&[1], vec![0.0]);
        let bp = Tensor::from_vec(&[1, 1, 1], vec![Q8_8.quantize(2.5)]);
        let y = conv_q(
            &x.quantize(Q8_8),
            &w.quantize(Q8_8),
            &b.quantize(Q8_8),
            1,
            0,
            false,
            Some(&bp),
            Q8_8,
        );
        assert_eq!(y.data[0], Q8_8.quantize(3.5));
    }

    #[test]
    fn residual_saturates() {
        let a = Tensor::from_vec(&[1], vec![i16::MAX]);
        let b = Tensor::from_vec(&[1], vec![100i16]);
        assert_eq!(residual_q(&a, &b, false).data[0], i16::MAX);
        let c = Tensor::from_vec(&[1], vec![-50i16]);
        assert_eq!(residual_q(&a, &c, true).data[0], i16::MAX - 50);
        let d = Tensor::from_vec(&[1], vec![i16::MIN]);
        assert_eq!(residual_q(&d, &c, true).data[0], 0);
    }
}
