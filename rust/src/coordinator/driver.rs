//! End-to-end drivers: build an [`Artifact`], load it into an
//! [`Engine`], infer (→ validate). Batched inference streams N frames
//! through one resident deployment.
//!
//! These are thin compatibility shims over the build/run split
//! (`Compiler::build` → `Engine::load` → `Engine::infer`): every sweep
//! job, tuning trial and paper table runs through the same two objects
//! the CLI's `repro build` / `repro run --artifact` / `repro serve`
//! expose.

use crate::arch::SnowflakeConfig;
use crate::compiler::{Artifact, CompileOptions, CompiledModel, Compiler};
use crate::engine::Engine;
use crate::model::graph::Graph;
use crate::model::weights::{synthetic_input, Weights};
use crate::refimpl;
use crate::sim::stats::Stats;
use crate::tensor::Tensor;
use std::sync::Arc;

/// Recover the compiled model from an unloaded engine artifact. The
/// single-shot drivers hold the only reference, so this is normally a
/// move, not a clone.
fn into_compiled(artifact: Arc<Artifact>) -> CompiledModel {
    match Arc::try_unwrap(artifact) {
        Ok(a) => a.compiled,
        Err(shared) => shared.compiled.clone(),
    }
}

/// Result of one simulated inference.
pub struct RunOutcome {
    pub compiled: CompiledModel,
    pub stats: Stats,
    pub machine: crate::sim::Machine,
}

/// Compile and simulate one inference with synthetic weights/input.
pub fn run_model(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    seed: u64,
) -> Result<RunOutcome, String> {
    let artifact = Compiler::new(cfg.clone())
        .options(opts.clone())
        .build(g)
        .map_err(|e| e.to_string())?;
    run_artifact(artifact, seed)
}

/// Simulate one inference from a prebuilt artifact: load it into a
/// fresh [`Engine`] with seeded synthetic weights, run one synthetic
/// input, and hand the machine back for canvas inspection. The
/// `repro run --artifact` path — bit-identical to [`run_model`] on the
/// graph/options the artifact was built from.
pub fn run_artifact(artifact: Artifact, seed: u64) -> Result<RunOutcome, String> {
    let cfg = artifact.cfg.clone();
    let x = synthetic_input(&artifact.graph, seed);
    let mut engine = Engine::new(cfg);
    let h = engine.load(artifact, seed).map_err(|e| e.to_string())?;
    let inf = engine.infer(h, &x).map_err(|e| e.to_string())?;
    let (artifact, machine) = engine.unload(h).map_err(|e| e.to_string())?;
    Ok(RunOutcome { compiled: into_compiled(artifact), stats: inf.stats, machine })
}

/// Result of a batched run: one compile + weight/program deployment,
/// `frames` inferences through the same machine.
pub struct BatchOutcome {
    pub compiled: CompiledModel,
    /// Per-frame simulation statistics (frames are independent, so
    /// cycles are identical across frames of the same input — the
    /// interesting aggregate is the amortized host wall time).
    pub per_frame: Vec<Stats>,
    /// Final generated layer's output words, per frame.
    pub outputs: Vec<Tensor<i16>>,
}

impl BatchOutcome {
    /// Total simulated cycles over the batch.
    pub fn total_cycles(&self) -> u64 {
        self.per_frame.iter().map(|s| s.cycles).sum()
    }
}

/// Compile once, deploy once, then stream `frames` synthetic inputs
/// through the resident model — the paper's deployment model, where
/// the host re-fills the image region and re-kicks the accelerator
/// while weights and instructions stay resident in CMA memory. Frame
/// `f` uses input seed `seed + f`, so frame 0 reproduces [`run_model`]
/// bit-for-bit.
pub fn run_batch(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    seed: u64,
    frames: usize,
) -> Result<BatchOutcome, String> {
    let artifact = Compiler::new(cfg.clone())
        .options(opts.clone())
        .build(g)
        .map_err(|e| e.to_string())?;
    run_batch_artifact(artifact, seed, frames)
}

/// As [`run_batch`] from a prebuilt artifact (`repro run --artifact
/// --batch N`).
pub fn run_batch_artifact(
    artifact: Artifact,
    seed: u64,
    frames: usize,
) -> Result<BatchOutcome, String> {
    let cfg = artifact.cfg.clone();
    let graph = artifact.graph.clone();
    let mut engine = Engine::new(cfg);
    let h = engine.load(artifact, seed).map_err(|e| e.to_string())?;
    let mut per_frame = Vec::with_capacity(frames);
    let mut outputs = Vec::with_capacity(frames);
    for f in 0..frames {
        let x = synthetic_input(&graph, seed + f as u64);
        let inf = engine.infer(h, &x).map_err(|e| format!("frame {f}: {e}"))?;
        outputs.push(inf.output);
        per_frame.push(inf.stats);
    }
    let (artifact, _machine) = engine.unload(h).map_err(|e| e.to_string())?;
    Ok(BatchOutcome { compiled: into_compiled(artifact), per_frame, outputs })
}

/// Run and validate every generated layer against the fixed-point
/// reference (§5.3 layer-by-layer validation). Returns per-layer
/// (name, words, mismatches).
pub fn validate_model(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    seed: u64,
) -> Result<(RunOutcome, Vec<(String, usize, usize)>), String> {
    let out = run_model(g, cfg, opts, seed)?;
    let w = Weights::init(g, seed);
    let x = synthetic_input(g, seed);
    let refs = refimpl::forward_q(g, &w, &x, out.compiled.plan.fmt);
    let mut rows = Vec::new();
    for lp in &out.compiled.plan.layers {
        if opts.skip_fc && matches!(lp.op, crate::compiler::layout::Lowered::Fc { .. }) {
            continue;
        }
        let node = lp.op.out_node();
        let cv = out.compiled.plan.canvases[&node];
        let got = crate::compiler::deploy::read_canvas(&out.machine, &cv);
        let diff = got.count_diff(&refs[node]);
        rows.push((format!("{}#{}", lp.op.name(), node), refs[node].len(), diff));
    }
    Ok((out, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{LayerKind, Shape};

    #[test]
    fn batch_frames_match_fresh_runs() {
        // Every batch frame must be bit-identical to a fresh machine
        // running that frame's input: machine reuse may not leak state.
        let mut g = Graph::new("b", Shape::new(16, 10, 10));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c",
        );
        let cfg = SnowflakeConfig::default();
        let opts = CompileOptions::default();
        let seed = 11;
        let batch = run_batch(&g, &cfg, &opts, seed, 3).unwrap();
        assert_eq!(batch.per_frame.len(), 3);
        for f in 0..3 {
            let w = crate::model::weights::Weights::init(&g, seed);
            let x = synthetic_input(&g, seed + f as u64);
            let refs = refimpl::forward_q(&g, &w, &x, batch.compiled.plan.fmt);
            assert_eq!(
                batch.outputs[f].count_diff(&refs[0]),
                0,
                "frame {f} diverged from the reference"
            );
        }
        // Identical timing per frame: same program, same machine state.
        assert_eq!(batch.per_frame[0].cycles, batch.per_frame[1].cycles);
        let fresh = run_model(&g, &cfg, &opts, seed).unwrap();
        assert_eq!(fresh.stats.cycles, batch.per_frame[0].cycles);
    }

    #[test]
    fn driver_runs_and_validates() {
        let mut g = Graph::new("t", Shape::new(16, 8, 8));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c",
        );
        let cfg = SnowflakeConfig::default();
        let (out, rows) = validate_model(&g, &cfg, &CompileOptions::default(), 5).unwrap();
        assert!(out.stats.cycles > 0);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].2, 0, "mismatches");
    }

    #[test]
    fn artifact_run_matches_direct_run() {
        // The build/run split may not perturb a single cycle: a
        // prebuilt artifact through the Engine equals compile-and-run.
        let mut g = Graph::new("a", Shape::new(16, 10, 10));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c",
        );
        let cfg = SnowflakeConfig::default();
        let opts = CompileOptions::default();
        let direct = run_model(&g, &cfg, &opts, 3).unwrap();
        let artifact = Compiler::new(cfg.clone()).options(opts).build(&g).unwrap();
        let via = run_artifact(artifact, 3).unwrap();
        assert_eq!(via.stats.comparable(), direct.stats.comparable());
        assert_eq!(via.compiled.program, direct.compiled.program);
        assert_eq!(via.machine.memory, direct.machine.memory, "final DRAM differs");
    }
}
