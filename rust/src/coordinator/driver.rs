//! End-to-end drivers: compile → deploy → simulate (→ validate).

use crate::arch::SnowflakeConfig;
use crate::compiler::{compile, deploy, CompileOptions, CompiledModel};
use crate::model::graph::Graph;
use crate::model::weights::{synthetic_input, Weights};
use crate::refimpl;
use crate::sim::stats::Stats;

/// Result of one simulated inference.
pub struct RunOutcome {
    pub compiled: CompiledModel,
    pub stats: Stats,
    pub machine: crate::sim::Machine,
}

/// Compile and simulate one inference with synthetic weights/input.
pub fn run_model(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    seed: u64,
) -> Result<RunOutcome, String> {
    let compiled = compile(g, cfg, opts).map_err(|e| e.to_string())?;
    let w = Weights::init(g, seed);
    let x = synthetic_input(g, seed);
    let mut m = deploy::make_machine_with(&compiled, g, &w, &x, cfg.clone());
    let stats = m.run().map_err(|e| e.to_string())?;
    Ok(RunOutcome { compiled, stats, machine: m })
}

/// Run and validate every generated layer against the fixed-point
/// reference (§5.3 layer-by-layer validation). Returns per-layer
/// (name, words, mismatches).
pub fn validate_model(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    seed: u64,
) -> Result<(RunOutcome, Vec<(String, usize, usize)>), String> {
    let out = run_model(g, cfg, opts, seed)?;
    let w = Weights::init(g, seed);
    let x = synthetic_input(g, seed);
    let refs = refimpl::forward_q(g, &w, &x, out.compiled.plan.fmt);
    let mut rows = Vec::new();
    for lp in &out.compiled.plan.layers {
        if opts.skip_fc && matches!(lp.op, crate::compiler::layout::Lowered::Fc { .. }) {
            continue;
        }
        let node = lp.op.out_node();
        let cv = out.compiled.plan.canvases[&node];
        let got = deploy::read_canvas(&out.machine, &cv);
        let diff = got.count_diff(&refs[node]);
        rows.push((format!("{}#{}", lp.op.name(), node), refs[node].len(), diff));
    }
    Ok((out, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{LayerKind, Shape};

    #[test]
    fn driver_runs_and_validates() {
        let mut g = Graph::new("t", Shape::new(16, 8, 8));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c",
        );
        let cfg = SnowflakeConfig::default();
        let (out, rows) = validate_model(&g, &cfg, &CompileOptions::default(), 5).unwrap();
        assert!(out.stats.cycles > 0);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].2, 0, "mismatches");
    }
}
