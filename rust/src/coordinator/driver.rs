//! End-to-end drivers: compile → deploy → simulate (→ validate), plus
//! batched inference (N frames through one compiled deployment).

use crate::arch::SnowflakeConfig;
use crate::compiler::layout::Lowered;
use crate::compiler::{compile, deploy, CompileOptions, CompiledModel};
use crate::model::graph::Graph;
use crate::model::weights::{synthetic_input, Weights};
use crate::refimpl;
use crate::sim::stats::Stats;
use crate::tensor::Tensor;

/// Result of one simulated inference.
pub struct RunOutcome {
    pub compiled: CompiledModel,
    pub stats: Stats,
    pub machine: crate::sim::Machine,
}

/// Compile and simulate one inference with synthetic weights/input.
pub fn run_model(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    seed: u64,
) -> Result<RunOutcome, String> {
    let compiled = compile(g, cfg, opts).map_err(|e| e.to_string())?;
    let w = Weights::init(g, seed);
    let x = synthetic_input(g, seed);
    let mut m = deploy::make_machine_with(&compiled, g, &w, &x, cfg.clone());
    let stats = m.run().map_err(|e| e.to_string())?;
    Ok(RunOutcome { compiled, stats, machine: m })
}

/// Result of a batched run: one compile + weight/program deployment,
/// `frames` inferences through the same machine.
pub struct BatchOutcome {
    pub compiled: CompiledModel,
    /// Per-frame simulation statistics (frames are independent, so
    /// cycles are identical across frames of the same input — the
    /// interesting aggregate is the amortized host wall time).
    pub per_frame: Vec<Stats>,
    /// Final generated layer's output words, per frame.
    pub outputs: Vec<Tensor<i16>>,
}

impl BatchOutcome {
    /// Total simulated cycles over the batch.
    pub fn total_cycles(&self) -> u64 {
        self.per_frame.iter().map(|s| s.cycles).sum()
    }
}

/// Compile once, deploy once, then stream `frames` synthetic inputs
/// through the machine, resetting only the dynamic state and the input
/// canvas between frames — the paper's deployment model, where the
/// host re-fills the image region and re-kicks the accelerator while
/// weights and instructions stay resident in CMA memory. Frame `f`
/// uses input seed `seed + f`, so frame 0 reproduces [`run_model`]
/// bit-for-bit.
pub fn run_batch(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    seed: u64,
    frames: usize,
) -> Result<BatchOutcome, String> {
    let compiled = compile(g, cfg, opts).map_err(|e| e.to_string())?;
    let w = Weights::init(g, seed);
    let x0 = synthetic_input(g, seed);
    let mut m = deploy::make_machine_with(&compiled, g, &w, &x0, cfg.clone());
    // The last layer that actually generated code (FC may be skipped).
    let last = compiled
        .plan
        .layers
        .iter()
        .rev()
        .find(|lp| !(opts.skip_fc && matches!(lp.op, Lowered::Fc { .. })))
        .ok_or_else(|| "model has no generated layers".to_string())?;
    let out_canvas = compiled.plan.canvases[&last.op.out_node()];

    let mut per_frame = Vec::with_capacity(frames);
    let mut outputs = Vec::with_capacity(frames);
    for f in 0..frames {
        if f > 0 {
            let x = synthetic_input(g, seed + f as u64);
            m.reset_for_inference();
            deploy::write_canvas(&mut m, &compiled.plan.input_canvas, &x, compiled.plan.fmt);
        }
        let stats = m.run().map_err(|e| format!("frame {f}: {e}"))?;
        outputs.push(deploy::read_canvas(&m, &out_canvas));
        per_frame.push(stats);
    }
    Ok(BatchOutcome { compiled, per_frame, outputs })
}

/// Run and validate every generated layer against the fixed-point
/// reference (§5.3 layer-by-layer validation). Returns per-layer
/// (name, words, mismatches).
pub fn validate_model(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
    seed: u64,
) -> Result<(RunOutcome, Vec<(String, usize, usize)>), String> {
    let out = run_model(g, cfg, opts, seed)?;
    let w = Weights::init(g, seed);
    let x = synthetic_input(g, seed);
    let refs = refimpl::forward_q(g, &w, &x, out.compiled.plan.fmt);
    let mut rows = Vec::new();
    for lp in &out.compiled.plan.layers {
        if opts.skip_fc && matches!(lp.op, crate::compiler::layout::Lowered::Fc { .. }) {
            continue;
        }
        let node = lp.op.out_node();
        let cv = out.compiled.plan.canvases[&node];
        let got = deploy::read_canvas(&out.machine, &cv);
        let diff = got.count_diff(&refs[node]);
        rows.push((format!("{}#{}", lp.op.name(), node), refs[node].len(), diff));
    }
    Ok((out, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{LayerKind, Shape};

    #[test]
    fn batch_frames_match_fresh_runs() {
        // Every batch frame must be bit-identical to a fresh machine
        // running that frame's input: machine reuse may not leak state.
        let mut g = Graph::new("b", Shape::new(16, 10, 10));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c",
        );
        let cfg = SnowflakeConfig::default();
        let opts = CompileOptions::default();
        let seed = 11;
        let batch = run_batch(&g, &cfg, &opts, seed, 3).unwrap();
        assert_eq!(batch.per_frame.len(), 3);
        for f in 0..3 {
            let w = crate::model::weights::Weights::init(&g, seed);
            let x = synthetic_input(&g, seed + f as u64);
            let refs = refimpl::forward_q(&g, &w, &x, batch.compiled.plan.fmt);
            assert_eq!(
                batch.outputs[f].count_diff(&refs[0]),
                0,
                "frame {f} diverged from the reference"
            );
        }
        // Identical timing per frame: same program, same machine state.
        assert_eq!(batch.per_frame[0].cycles, batch.per_frame[1].cycles);
        let fresh = run_model(&g, &cfg, &opts, seed).unwrap();
        assert_eq!(fresh.stats.cycles, batch.per_frame[0].cycles);
    }

    #[test]
    fn driver_runs_and_validates() {
        let mut g = Graph::new("t", Shape::new(16, 8, 8));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 8, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c",
        );
        let cfg = SnowflakeConfig::default();
        let (out, rows) = validate_model(&g, &cfg, &CompileOptions::default(), 5).unwrap();
        assert!(out.stats.cycles > 0);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].2, 0, "mismatches");
    }
}
