//! PJRT golden-model cross-check: execute the AOT artifacts (L2 jax
//! graphs with the L1 Pallas kernel inlined) from rust and compare them
//! word-for-word against [`crate::refimpl`] — closing the loop between
//! the python build path and the rust run path. Shapes mirror
//! `python/compile/model.py`.

use crate::fixed::Q8_8;
use crate::refimpl::conv::{conv_q, residual_q};
use crate::runtime::{artifacts_dir, Runtime};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};

fn rand_q(rng: &mut Rng, shape: &[usize], amp: f32) -> Tensor<i16> {
    let mut t = Tensor::zeros(shape);
    for v in t.data.iter_mut() {
        *v = Q8_8.quantize(rng.f32_range(-amp, amp));
    }
    t
}

/// Run every artifact check; returns a summary line on success.
pub fn run_golden() -> Result<String> {
    let dir = artifacts_dir();
    if !dir.join("conv3x3_q88.hlo.txt").exists() {
        bail!(
            "artifacts not found in {dir:?}; run `make artifacts` (python build path) first"
        );
    }
    let rt = Runtime::cpu().context("PJRT client")?;
    let mut rng = Rng::new(20260711);
    let mut checked = 0usize;

    // conv3x3: x[16,12,12], w[8,16,3,3], b[8], pad 1, relu.
    {
        let art = rt.load_hlo_text(&dir.join("conv3x3_q88.hlo.txt"))?;
        let x = rand_q(&mut rng, &[16, 12, 12], 2.0);
        let w = rand_q(&mut rng, &[8, 16, 3, 3], 0.5);
        let b = rand_q(&mut rng, &[8], 0.5);
        let out = art.run_i16(&[
            (&x.data, &x.shape),
            (&w.data, &w.shape),
            (&b.data, &b.shape),
        ])?;
        let want = conv_q(&x, &w, &b, 1, 1, true, None, Q8_8);
        if out[0] != want.data {
            let diffs = out[0].iter().zip(&want.data).filter(|(a, b)| a != b).count();
            bail!("conv3x3 golden mismatch: {diffs}/{} words", want.len());
        }
        checked += 1;
    }

    // conv1x1 stride 2: x[32,10,10], w[16,32,1,1], b[16].
    {
        let art = rt.load_hlo_text(&dir.join("conv1x1_q88.hlo.txt"))?;
        let x = rand_q(&mut rng, &[32, 10, 10], 2.0);
        let w = rand_q(&mut rng, &[16, 32, 1, 1], 0.5);
        let b = rand_q(&mut rng, &[16], 0.5);
        let out = art.run_i16(&[
            (&x.data, &x.shape),
            (&w.data, &w.shape),
            (&b.data, &b.shape),
        ])?;
        let want = conv_q(&x, &w, &b, 2, 0, false, None, Q8_8);
        if out[0] != want.data {
            bail!("conv1x1 golden mismatch");
        }
        checked += 1;
    }

    // Identity residual block: x[16,8,8], two 3x3 convs + bypass + relu.
    {
        let art = rt.load_hlo_text(&dir.join("block_q88.hlo.txt"))?;
        let x = rand_q(&mut rng, &[16, 8, 8], 1.5);
        let w1 = rand_q(&mut rng, &[16, 16, 3, 3], 0.3);
        let b1 = rand_q(&mut rng, &[16], 0.3);
        let w2 = rand_q(&mut rng, &[16, 16, 3, 3], 0.3);
        let b2 = rand_q(&mut rng, &[16], 0.3);
        let out = art.run_i16(&[
            (&x.data, &x.shape),
            (&w1.data, &w1.shape),
            (&b1.data, &b1.shape),
            (&w2.data, &w2.shape),
            (&b2.data, &b2.shape),
        ])?;
        let h = conv_q(&x, &w1, &b1, 1, 1, true, None, Q8_8);
        let h = conv_q(&h, &w2, &b2, 1, 1, false, None, Q8_8);
        let want = residual_q(&h, &x, true);
        if out[0] != want.data {
            bail!("residual block golden mismatch");
        }
        checked += 1;
    }

    // maxpool 2x2/2 on int16.
    {
        let art = rt.load_hlo_text(&dir.join("maxpool_q88.hlo.txt"))?;
        let x = rand_q(&mut rng, &[16, 12, 12], 2.0);
        let out = art.run_i16(&[(&x.data, &x.shape)])?;
        let want = crate::refimpl::pool::maxpool_q(&x, 2, 2, 2, 0);
        if out[0] != want.data {
            bail!("maxpool golden mismatch");
        }
        checked += 1;
    }

    Ok(format!(
        "golden: {checked} artifacts bit-exact vs refimpl on {} ({} platform)",
        dir.display(),
        rt.platform()
    ))
}
