//! Coordination layer: end-to-end drivers behind the CLI, the paper-
//! table generators (Tables 1–3, Figure 4, the §5.3 accuracy profile),
//! the batched-inference + parallel sweep harness and the PJRT
//! golden-model cross-check (feature `pjrt`).

pub mod driver;
#[cfg(feature = "pjrt")]
pub mod golden;
pub mod report;
pub mod sweep;
pub mod tune;

pub use driver::{
    run_artifact, run_batch, run_batch_artifact, run_model, validate_model, BatchOutcome,
    RunOutcome,
};
pub use sweep::{run_sweep, SweepJob, SweepOutcome};
pub use tune::{tune_measured, TuneOutcome};
