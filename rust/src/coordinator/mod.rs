//! Coordination layer: end-to-end drivers behind the CLI, the paper-
//! table generators (Tables 1–3, Figure 4, the §5.3 accuracy profile)
//! and the PJRT golden-model cross-check.

pub mod driver;
pub mod golden;
pub mod report;

pub use driver::{run_model, validate_model, RunOutcome};
