//! Measured schedule tuning (`--tune measured`,
//! `TuneMode::Measured`): compile the top-K cost-model-ranked schedule
//! candidates per conv layer and run each through the event-driven
//! simulator, keeping the configuration with the fewest *measured*
//! cycles.
//!
//! Strategy: one greedy coordinate-descent pass over the conv layers of
//! the full model. The heuristic, analytical and forced-Kloop baselines
//! are all simulated first and the fastest seeds the incumbent, so the
//! result can never be worse than any of the three — the
//! `tuned ≤ min(heuristic, analytical, forced-Kloop)` guarantee
//! `benches/tuning.rs` gates on. (Forced-Kloop matters as a seed since
//! the Mloop-family skeletons exist: if the model ever mispredicts an
//! Mloop/rotation flip, the all-Kloop configuration is still trialed
//! and wins back the regression.) Each per-layer candidate swap is
//! evaluated on the *whole model* (same canvases, margins, and DMA
//! context the production compile sees), not on an isolated layer, so
//! measured numbers are exactly the numbers that ship. A candidate
//! whose compile fails (e.g. an Mloop block that outgrows its icache
//! bank) is skipped, not fatal.

use super::driver::{self, RunOutcome};
use crate::arch::SnowflakeConfig;
use crate::compiler::cost::{self, Schedule};
use crate::compiler::decide::OpPlan;
use crate::compiler::layout::{LayerPlan, Lowered, Plan};
use crate::compiler::{CompileOptions, ScheduleMap, TuneMode};
use crate::model::graph::Graph;

/// Result of a measured tuning run.
pub struct TuneOutcome {
    /// The winning configuration's full run (compiled model + stats).
    pub outcome: RunOutcome,
    /// Winning per-conv-layer schedules (node id -> schedule), ready to
    /// replay through `CompileOptions::schedules`.
    pub schedules: ScheduleMap,
    /// Tune mode to pair with `schedules` when recompiling the winning
    /// configuration. Non-conv decisions (maxpool strip heights) follow
    /// the tune mode, not the schedule map, so an exact replay must use
    /// the mode the incumbent was compiled under — `Heuristic` when the
    /// heuristic baseline won outright, `Analytical` otherwise.
    pub replay_tune: TuneMode,
    pub heuristic_cycles: u64,
    pub analytical_cycles: u64,
    /// The all-Kloop baseline (analytical tuning under
    /// `force_loop_order: Kloop`) — the third seed of the incumbent.
    pub forced_kloop_cycles: u64,
    /// Full-model simulations spent (3 baselines + candidate swaps).
    pub trials: usize,
    /// Candidate swaps that beat the incumbent.
    pub improved_swaps: usize,
}

impl TuneOutcome {
    pub fn tuned_cycles(&self) -> u64 {
        self.outcome.stats.cycles
    }
}

/// Rebuild the tuner's geometry view of one planned conv layer (also
/// used by `repro explain`'s rotation diagnosis).
pub fn conv_geom_for(plan: &Plan, lp: &LayerPlan) -> Option<(usize, cost::ConvGeom)> {
    let OpPlan::Conv(d) = &lp.decision else { return None };
    let in_cv = plan.in_canvas(&lp.op);
    let byp_row_words = match &lp.op {
        Lowered::Conv { bypass: Some(b), .. } => plan.canvases[b].row_words(),
        _ => 0,
    };
    Some((
        lp.op.out_node(),
        cost::ConvGeom {
            kh: d.kh,
            stride: d.stride,
            h_out: d.h_out,
            w_out: d.w_out,
            row_words_in: in_cv.row_words(),
            row_read: d.geom.row_read,
            n_segs: d.geom.segs.len(),
            kernel_words: d.kernel_words,
            k_groups: d.k_groups,
            c_pad_out: d.c_pad_out,
            has_bypass: d.has_bypass,
            byp_row_words,
            max_rows: d.max_rows,
            dbuf_w: d.dbuf_w,
        },
    ))
}

/// The schedules a compiled plan actually used, keyed by node id.
/// (Thin alias of [`Plan::conv_schedules`], which artifacts record.)
pub fn plan_schedules(plan: &Plan) -> ScheduleMap {
    plan.conv_schedules()
}

/// Measured tuning of one model: greedy per-layer refinement over the
/// top-`top_k` predicted candidates, seeded by the faster of the
/// heuristic and analytical baselines.
pub fn tune_measured(
    g: &Graph,
    cfg: &SnowflakeConfig,
    base: &CompileOptions,
    seed: u64,
    top_k: usize,
) -> Result<TuneOutcome, String> {
    let top_k = top_k.max(1);
    let run = |schedules: ScheduleMap, tune: TuneMode| -> Result<RunOutcome, String> {
        let opts = CompileOptions { tune, schedules, ..base.clone() };
        driver::run_model(g, cfg, &opts, seed)
    };

    let heuristic = run(ScheduleMap::new(), TuneMode::Heuristic)?;
    let analytical = run(ScheduleMap::new(), TuneMode::Analytical)?;
    // Third baseline: the best all-Kloop configuration. Its schedules
    // replay exactly through the schedule map (explicit orders win over
    // the tuner), so it can seed the incumbent like the other two —
    // *unless* the caller already forces a loop order: the caller's
    // force would override the replayed schedule map at compile time
    // (`decide`: force > schedules), so a forced-Kloop incumbent could
    // not be reproduced and is skipped instead.
    let forced_kloop = if base.force_loop_order.is_none() {
        let opts = CompileOptions {
            tune: TuneMode::Analytical,
            schedules: ScheduleMap::new(),
            force_loop_order: Some(crate::compiler::LoopOrder::Kloop),
            ..base.clone()
        };
        Some(driver::run_model(g, cfg, &opts, seed)?)
    } else {
        None
    };
    let heuristic_cycles = heuristic.stats.cycles;
    let analytical_cycles = analytical.stats.cycles;
    // When the third baseline is skipped it mirrors the better of the
    // other two, keeping the `tuned <= forced_kloop_cycles` guarantee.
    let forced_kloop_cycles = forced_kloop
        .as_ref()
        .map(|r| r.stats.cycles)
        .unwrap_or_else(|| analytical_cycles.min(heuristic_cycles));
    let ran_forced = forced_kloop.is_some();

    // Seed the incumbent with the fastest baseline; the result can only
    // improve from here.
    // Stable sort with analytical first: ties keep the pre-ISSUE-5
    // preference (analytical over heuristic when equal).
    let mut candidates_best = vec![
        (analytical_cycles, TuneMode::Analytical, analytical),
        (heuristic_cycles, TuneMode::Heuristic, heuristic),
    ];
    if let Some(fk) = forced_kloop {
        candidates_best.push((fk.stats.cycles, TuneMode::Analytical, fk));
    }
    candidates_best.sort_by_key(|(c, _, _)| *c);
    let (_, seed_mode, seed_outcome) = candidates_best.into_iter().next().expect("baselines");
    let schedules0 = plan_schedules(&seed_outcome.compiled.plan);
    let (mut best, mut schedules, mut replay_tune) = (seed_outcome, schedules0, seed_mode);
    let mut trials = 2 + ran_forced as usize;
    let mut improved_swaps = 0usize;

    // Candidate rankings per conv layer, from the incumbent's plan
    // (geometry and constraint caps are schedule-independent).
    let rank_opts = CompileOptions { tune: TuneMode::Analytical, ..base.clone() };
    let layer_cands: Vec<(usize, Vec<Schedule>)> = best
        .compiled
        .plan
        .layers
        .iter()
        .filter_map(|lp| conv_geom_for(&best.compiled.plan, lp))
        .map(|(node, geom)| {
            let cands: Vec<Schedule> = cost::ranked(&geom, cfg, &rank_opts)
                .into_iter()
                .take(top_k)
                .map(|(s, _)| s)
                .collect();
            (node, cands)
        })
        .collect();

    for (node, cands) in layer_cands {
        for cand in cands {
            if schedules.get(&node) == Some(&cand) {
                continue;
            }
            let mut swapped = schedules.clone();
            swapped.insert(node, cand);
            trials += 1;
            match run(swapped.clone(), TuneMode::Analytical) {
                Ok(r) if r.stats.cycles < best.stats.cycles => {
                    best = r;
                    schedules = swapped;
                    // Trials compile under Analytical, so a winning swap
                    // moves the replay mode there.
                    replay_tune = TuneMode::Analytical;
                    improved_swaps += 1;
                }
                // Slower/equal candidates keep the incumbent; a failed
                // candidate compile (oversized block etc.) is skipped.
                Ok(_) | Err(_) => {}
            }
        }
    }

    // Publish the measured winners to the in-process measurement cache:
    // from here on, any `compile()` under `TuneMode::Measured` reuses
    // them per layer (keyed on config + geometry, so identical layers
    // in *other* models hit too) instead of re-deriving analytically.
    for lp in &best.compiled.plan.layers {
        if let Some((node, geom)) = conv_geom_for(&best.compiled.plan, lp) {
            if let Some(s) = schedules.get(&node) {
                crate::compiler::measure_cache::record(cfg, &geom, *s);
            }
        }
    }

    Ok(TuneOutcome {
        outcome: best,
        schedules,
        replay_tune,
        heuristic_cycles,
        analytical_cycles,
        forced_kloop_cycles,
        trials,
        improved_swaps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{LayerKind, Shape};

    /// A small two-tile conv where the measured tuner has real
    /// candidates to try; the invariant under test is the guarantee the
    /// CI gate leans on: tuned cycles ≤ both baselines.
    #[test]
    fn measured_tuning_never_loses_to_baselines() {
        let mut g = Graph::new("tune_small", Shape::new(16, 24, 24));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch: 32, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c1",
        );
        let cfg = SnowflakeConfig::default();
        let out = tune_measured(&g, &cfg, &CompileOptions::default(), 7, 2).unwrap();
        assert!(out.tuned_cycles() <= out.heuristic_cycles, "tuned lost to the heuristic");
        assert!(out.tuned_cycles() <= out.analytical_cycles, "tuned lost to analytical");
        assert!(out.tuned_cycles() <= out.forced_kloop_cycles, "tuned lost to forced Kloop");
        assert!(out.trials >= 3);
        assert!(!out.schedules.is_empty());
        // Replaying the winning schedules under the recorded mode
        // reproduces the winning run exactly (pool heights included).
        let opts = CompileOptions {
            tune: out.replay_tune,
            schedules: out.schedules.clone(),
            ..Default::default()
        };
        let replay = driver::run_model(&g, &cfg, &opts, 7).unwrap();
        assert_eq!(replay.stats.cycles, out.tuned_cycles(), "schedule replay diverged");
    }

    /// ISSUE 8 satellite: `TuneMode::Measured` inside `compile()` is no
    /// longer a pass-through — it consults the in-process measurement
    /// cache. Cold compile = miss + analytical fallback; after a
    /// `tune_measured` run the same compile hits and picks the measured
    /// winner without a single simulation.
    #[test]
    fn measured_compile_consults_the_measurement_cache() {
        use crate::compiler::{measure_cache, Compiler};
        // A geometry unique to this test (8ch 36x36) so parallel tests
        // can neither satisfy these lookups nor overwrite the entry.
        let mut g = Graph::new("tune_cache", Shape::new(8, 36, 36));
        g.push_seq(
            LayerKind::Conv { in_ch: 8, out_ch: 16, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c1",
        );
        let cfg = SnowflakeConfig::default();
        let measured = CompileOptions {
            tune: TuneMode::Measured { top_k: 2 },
            ..Default::default()
        };
        let before = measure_cache::counters();
        let cold = Compiler::new(cfg.clone()).options(measured.clone()).build(&g).unwrap();
        let mid = measure_cache::counters();
        assert!(mid.misses >= before.misses + 1, "cold measured compile must miss");
        let analytical = Compiler::new(cfg.clone()).build(&g).unwrap();
        assert_eq!(
            cold.schedules, analytical.schedules,
            "a cache miss falls back to the analytical pick"
        );
        let out = tune_measured(&g, &cfg, &CompileOptions::default(), 3, 2).unwrap();
        let warm = Compiler::new(cfg.clone()).options(measured).build(&g).unwrap();
        let after = measure_cache::counters();
        assert!(after.hits >= mid.hits + 1, "post-tune measured compile must hit");
        assert_eq!(
            warm.schedules, out.schedules,
            "the hit compiles the layer under its measured winner"
        );
    }
}
