//! Paper-table generators. Each function returns structured rows (used
//! by benches and tests) and can print the table in the paper's format.
//! DESIGN.md's experiment index maps each to its source (E1–E7).
//!
//! All timing tables are produced through the parallel sweep harness
//! ([`crate::coordinator::sweep`]): each table builds a list of
//! independent jobs and fans them across host threads; [`run_grid`]
//! concatenates every table's jobs plus the ablation variants into one
//! sweep so the whole paper regenerates in a single invocation
//! (`repro sweep` / `cargo bench --bench grid`).

use crate::arch::SnowflakeConfig;
use crate::compiler::{cost, decide, layout, BalancePolicy, CompileOptions, LoopOrder, TuneMode};
use crate::fixed::{QFormat, Q5_11, Q8_8};
use crate::model::graph::Graph;
use crate::model::layer::{LayerKind, Shape};
use crate::model::weights::{synthetic_input, Weights};
use crate::model::zoo;
use crate::refimpl;
use crate::util::rng::Rng;

use super::sweep::{self, SweepJob, SweepOutcome};
use super::{driver, tune};

// ---------------------------------------------------------------------
// Table 1: hand vs auto
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub layer: String,
    pub hand_ms: f64,
    pub auto_ms: f64,
    pub hand_instrs: usize,
    pub auto_instrs: usize,
}

/// Jobs behind Table 1: (hand, auto) per layer, in that order.
pub fn table1_jobs(cfg: &SnowflakeConfig, seed: u64) -> Vec<SweepJob> {
    let mut jobs = Vec::new();
    for g in zoo::table1_layers() {
        let hand = CompileOptions { smart_delay_slots: true, ..Default::default() };
        jobs.push(
            SweepJob::new(format!("table1/{}/hand", g.name), g.clone(), cfg, hand).seed(seed),
        );
        jobs.push(
            SweepJob::new(format!("table1/{}/auto", g.name), g, cfg, CompileOptions::default())
                .seed(seed),
        );
    }
    jobs
}

fn table1_rows(outs: &[SweepOutcome], cfg: &SnowflakeConfig) -> Vec<Table1Row> {
    outs.chunks(2)
        .map(|pair| {
            let layer = pair[0]
                .name
                .strip_prefix("table1/")
                .and_then(|s| s.strip_suffix("/hand"))
                .unwrap_or(&pair[0].name)
                .to_string();
            Table1Row {
                layer,
                hand_ms: pair[0].stats.time_ms(cfg),
                auto_ms: pair[1].stats.time_ms(cfg),
                hand_instrs: pair[0].code_len,
                auto_instrs: pair[1].code_len,
            }
        })
        .collect()
}

/// E1/E6: hand-optimized vs auto-generated code on the Table 1 layers.
pub fn table1(cfg: &SnowflakeConfig, seed: u64) -> Vec<Table1Row> {
    table1_rows(&sweep::run_sweep_strict(&table1_jobs(cfg, seed), None), cfg)
}

pub fn print_table1(rows: &[Table1Row]) {
    println!("Table 1: hand optimized code (hand) versus auto-generated instructions (auto)");
    println!("{:<24} {:>6} {:>11} {:>8}", "Layer", "Code", "Time [ms]", "Instrs");
    let mut dhand = 0usize;
    let mut dauto = 0usize;
    for r in rows {
        println!("{:<24} {:>6} {:>11.3} {:>8}", r.layer, "Hand", r.hand_ms, r.hand_instrs);
        println!("{:<24} {:>6} {:>11.3} {:>8}", "", "Auto", r.auto_ms, r.auto_instrs);
        dhand += r.hand_instrs;
        dauto += r.auto_instrs;
    }
    println!("(auto - hand) instruction delta over all layers: {}", dauto as i64 - dhand as i64);
}

// ---------------------------------------------------------------------
// Table 2: model results
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table2Row {
    pub model: String,
    pub exec_ms: f64,
    pub bw_gbs: f64,
    pub fps: f64,
    pub cu_util: f64,
    pub instrs: usize,
}

/// Jobs behind Table 2, one full model per job (FC excluded, as the
/// paper does: "Execution time for all models does not account for FC
/// layer times").
pub fn table2_jobs(cfg: &SnowflakeConfig, models: &[&str], seed: u64) -> Vec<SweepJob> {
    models
        .iter()
        .map(|name| {
            let g = zoo::by_name(name).unwrap_or_else(|| panic!("unknown model {name}"));
            let opts = CompileOptions { skip_fc: true, ..Default::default() };
            SweepJob::new(format!("table2/{}", g.name), g, cfg, opts).seed(seed)
        })
        .collect()
}

fn table2_rows(outs: &[SweepOutcome], cfg: &SnowflakeConfig) -> Vec<Table2Row> {
    outs.iter()
        .map(|o| {
            let ms = o.stats.time_ms(cfg);
            Table2Row {
                model: o.name.strip_prefix("table2/").unwrap_or(&o.name).to_string(),
                exec_ms: ms,
                bw_gbs: o.stats.bandwidth_gbs(cfg),
                fps: 1000.0 / ms,
                cu_util: o.stats.cu_utilization(),
                instrs: o.code_len,
            }
        })
        .collect()
}

/// E2/E7: full-model execution time and bandwidth.
pub fn table2(cfg: &SnowflakeConfig, models: &[&str], seed: u64) -> Vec<Table2Row> {
    table2_rows(&sweep::run_sweep_strict(&table2_jobs(cfg, models, seed), None), cfg)
}

pub fn print_table2(rows: &[Table2Row]) {
    println!("Table 2: results for models using Snowflake's compiler");
    println!(
        "{:<14} {:>14} {:>10} {:>8} {:>8} {:>8}",
        "Model", "Exec. Time[ms]", "BW [GB/s]", "fps", "util%", "instrs"
    );
    for r in rows {
        println!(
            "{:<14} {:>14.2} {:>10.2} {:>8.1} {:>8.1} {:>8}",
            r.model,
            r.exec_ms,
            r.bw_gbs,
            r.fps,
            r.cu_util * 100.0,
            r.instrs
        );
    }
}

// ---------------------------------------------------------------------
// Table 3: speedup vs load imbalance
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table3Row {
    pub policy: String,
    pub imbalance_pct: f64,
    pub exec_ms: f64,
    pub speedup: f64,
}

/// The Table 3 layer: CONV 1×1, 1024 in, 2048 out, stride 2 (a ResNet50
/// layer4 downsample, 14×14 input).
pub fn table3_layer() -> Graph {
    let mut g = Graph::new("14x14,1x1,1024,2048,2,0", Shape::new(1024, 14, 14));
    g.push_seq(
        LayerKind::Conv { in_ch: 1024, out_ch: 2048, kh: 1, kw: 1, stride: 2, pad: 0, relu: false },
        "conv",
    );
    g
}

/// Jobs behind Table 3: the balance policies from finest to the paper's
/// worst case.
pub fn table3_jobs(cfg: &SnowflakeConfig, seed: u64) -> Vec<SweepJob> {
    let policies: Vec<(&str, BalancePolicy)> = vec![
        ("greedy/4", BalancePolicy::Greedy { split: 4 }),
        ("greedy/2", BalancePolicy::Greedy { split: 2 }),
        ("greedy/1", BalancePolicy::Greedy { split: 1 }),
        ("two-units", BalancePolicy::TwoUnits),
        ("one-unit", BalancePolicy::OneUnit),
    ];
    policies
        .into_iter()
        .map(|(name, p)| {
            // Heuristic mode: Table 3 measures the *requested* policy;
            // the tuner would override the Greedy split per layer.
            let opts = CompileOptions {
                balance: p,
                tune: TuneMode::Heuristic,
                ..Default::default()
            };
            SweepJob::new(format!("table3/{name}"), table3_layer(), cfg, opts).seed(seed)
        })
        .collect()
}

fn table3_rows(outs: &[SweepOutcome], cfg: &SnowflakeConfig) -> Vec<Table3Row> {
    let mut rows: Vec<Table3Row> = outs
        .iter()
        .map(|o| Table3Row {
            policy: o.name.strip_prefix("table3/").unwrap_or(&o.name).to_string(),
            imbalance_pct: o.stats.load_imbalance_pct(),
            exec_ms: o.stats.time_ms(cfg),
            speedup: 0.0,
        })
        .collect();
    let worst = rows.iter().map(|r| r.exec_ms).fold(0.0f64, f64::max);
    for r in rows.iter_mut() {
        r.speedup = worst / r.exec_ms;
    }
    rows
}

/// E3: speedup vs measured load imbalance across balance policies.
pub fn table3(cfg: &SnowflakeConfig, seed: u64) -> Vec<Table3Row> {
    table3_rows(&sweep::run_sweep_strict(&table3_jobs(cfg, seed), None), cfg)
}

pub fn print_table3(rows: &[Table3Row]) {
    println!("Table 3: speed up versus load imbalance (CONV 1x1, 1024->2048, stride 2)");
    println!("{:<12} {:>16} {:>11} {:>9}", "Policy", "Load Balance [%]", "Time [ms]", "Speed up");
    for r in rows {
        println!(
            "{:<12} {:>16.0} {:>11.3} {:>9.3}",
            r.policy, r.imbalance_pct, r.exec_ms, r.speedup
        );
    }
}

// ---------------------------------------------------------------------
// Ablations + the one-invocation grid
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub variant: String,
    pub exec_ms: f64,
    pub instrs: usize,
}

/// The AlexNet-conv2-class layer every ablation toggles in isolation.
pub fn ablation_layer() -> Graph {
    let mut g = Graph::new("27x27,5x5,64,192,1,2", Shape::new(64, 27, 27));
    g.push_seq(
        LayerKind::Conv { in_ch: 64, out_ch: 192, kh: 5, kw: 5, stride: 1, pad: 2, relu: true },
        "conv2",
    );
    g
}

/// Jobs behind the ablation table: each DESIGN.md design choice toggled
/// in isolation (delay-slot filling, maps-load splitting, vector-queue
/// depth, DMA setup cost). First job is the baseline.
pub fn ablation_jobs(cfg: &SnowflakeConfig, seed: u64) -> Vec<SweepJob> {
    // Ablations toggle the *seed* knobs in isolation; heuristic mode
    // keeps the tuner from re-deciding the knob under ablation.
    let base = CompileOptions { tune: TuneMode::Heuristic, ..Default::default() };
    let mut jobs = vec![
        SweepJob::new("ablate/baseline (auto, greedy/2)", ablation_layer(), cfg, base.clone())
            .seed(seed),
        SweepJob::new(
            "ablate/smart delay slots (hand)",
            ablation_layer(),
            cfg,
            CompileOptions { smart_delay_slots: true, ..base.clone() },
        )
        .seed(seed),
    ];
    for split in [1usize, 4] {
        jobs.push(
            SweepJob::new(
                format!("ablate/maps-load split = {split}"),
                ablation_layer(),
                cfg,
                CompileOptions { balance: BalancePolicy::Greedy { split }, ..base.clone() },
            )
            .seed(seed),
        );
    }
    for depth in [4usize, 32] {
        let c = SnowflakeConfig { vector_queue_depth: depth, ..cfg.clone() };
        jobs.push(
            SweepJob::new(
                format!("ablate/vector queue depth = {depth}"),
                ablation_layer(),
                &c,
                base.clone(),
            )
            .seed(seed),
        );
    }
    for setup in [8u64, 256] {
        let c = SnowflakeConfig { dma_setup_cycles: setup, ..cfg.clone() };
        jobs.push(
            SweepJob::new(
                format!("ablate/dma setup = {setup} cycles"),
                ablation_layer(),
                &c,
                base.clone(),
            )
            .seed(seed),
        );
    }
    jobs
}

fn ablation_rows(outs: &[SweepOutcome], cfg: &SnowflakeConfig) -> Vec<AblationRow> {
    outs.iter()
        .map(|o| AblationRow {
            variant: o.name.strip_prefix("ablate/").unwrap_or(&o.name).to_string(),
            exec_ms: o.stats.time_ms(cfg),
            instrs: o.code_len,
        })
        .collect()
}

/// Everything [`run_grid`] produced, plus sweep telemetry.
pub struct GridResults {
    pub table1: Vec<Table1Row>,
    pub table2: Vec<Table2Row>,
    pub table3: Vec<Table3Row>,
    pub ablations: Vec<AblationRow>,
    pub jobs: usize,
    pub threads: usize,
    pub wall: std::time::Duration,
    pub total_cycles: u64,
}

/// E1–E3 + ablations as one parallel sweep: the full paper grid in a
/// single invocation (`repro sweep`, `cargo bench --bench grid`).
/// `fast` drops ResNet50 from Table 2.
pub fn run_grid(
    cfg: &SnowflakeConfig,
    seed: u64,
    fast: bool,
    threads: Option<usize>,
) -> GridResults {
    let models: &[&str] =
        if fast { &["alexnet", "resnet18"] } else { &["alexnet", "resnet18", "resnet50"] };
    let mut jobs = table1_jobs(cfg, seed);
    let n1 = jobs.len();
    jobs.extend(table2_jobs(cfg, models, seed));
    let n2 = jobs.len();
    jobs.extend(table3_jobs(cfg, seed));
    let n3 = jobs.len();
    jobs.extend(ablation_jobs(cfg, seed));

    let t0 = std::time::Instant::now();
    let outs = sweep::run_sweep_strict(&jobs, threads);
    GridResults {
        table1: table1_rows(&outs[..n1], cfg),
        table2: table2_rows(&outs[n1..n2], cfg),
        table3: table3_rows(&outs[n2..n3], cfg),
        ablations: ablation_rows(&outs[n3..], cfg),
        jobs: outs.len(),
        threads: sweep::resolve_threads(outs.len(), threads),
        wall: t0.elapsed(),
        total_cycles: outs.iter().map(|o| o.stats.cycles).sum(),
    }
}

pub fn print_grid(g: &GridResults) {
    print_table1(&g.table1);
    println!();
    print_table2(&g.table2);
    println!();
    print_table3(&g.table3);
    println!();
    println!("Ablations (27x27,5x5,64,192 conv, each knob toggled in isolation):");
    println!("{:<34} {:>10} {:>8}", "variant", "time [ms]", "instrs");
    for r in &g.ablations {
        println!("{:<34} {:>10.3} {:>8}", r.variant, r.exec_ms, r.instrs);
    }
    let secs = g.wall.as_secs_f64().max(1e-9);
    println!(
        "\ngrid: {} jobs on {} threads in {:.2}s — {:.1}M simulated cycles ({:.1}M cycles/s host)",
        g.jobs,
        g.threads,
        secs,
        g.total_cycles as f64 / 1e6,
        g.total_cycles as f64 / 1e6 / secs
    );
}

// ---------------------------------------------------------------------
// Figure 4: Mloop vs Kloop required bandwidth
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub tag: char,
    pub layer: String,
    pub mloop_gbs: f64,
    pub kloop_gbs: f64,
}

/// E4: required memory bandwidth per loop order for 8 conv examples
/// (A, B from AlexNet; C–F from ResNet18/50 middles; G, H the big
/// ResNet50 layers whose Mloop demand exceeds the board's 4.2 GB/s).
pub fn fig4(cfg: &SnowflakeConfig) -> Vec<Fig4Row> {
    // (input hxw, k, in_ch, out_ch, stride, pad)
    let shapes: [(usize, usize, usize, usize, usize, usize); 8] = [
        (27, 5, 64, 192, 1, 2),    // A: AlexNet conv2
        (13, 3, 384, 256, 1, 1),   // B: AlexNet conv4
        (56, 3, 64, 64, 1, 1),     // C: ResNet18 layer1
        (28, 3, 128, 128, 1, 1),   // D: ResNet18 layer2
        (14, 3, 256, 256, 1, 1),   // E: ResNet18 layer3
        (28, 3, 256, 256, 1, 1),   // F: ResNet50 layer2-scale conv
        (14, 1, 1024, 2048, 2, 0), // G: ResNet50 layer4 downsample
        (7, 1, 2048, 512, 1, 0),   // H: ResNet50 layer4 bottleneck reduce
    ];
    let mut rows = Vec::new();
    for (i, &(n, k, ic, oc, s, p)) in shapes.iter().enumerate() {
        let in_shape = Shape::new(ic, n, n);
        let kind =
            LayerKind::Conv { in_ch: ic, out_ch: oc, kh: k, kw: k, stride: s, pad: p, relu: false };
        let out = kind.out_shape(in_shape);
        let op = layout::Lowered::Conv {
            node: 0,
            src: None,
            bypass: None,
            in_ch: ic,
            out_ch: oc,
            kh: k,
            kw: k,
            stride: s,
            pad: p,
            relu: false,
        };
        // Heuristic mode: Figure 4 is the paper's §6.2 analysis at the
        // capacity-maximal tile height, independent of the tuner.
        let fig_opts = CompileOptions { tune: TuneMode::Heuristic, ..Default::default() };
        let d = decide::decide(&op, in_shape, out, p, 0, cfg, &fig_opts).expect("decide");
        let decide::OpPlan::Conv(c) = d else { unreachable!() };
        rows.push(Fig4Row {
            tag: (b'A' + i as u8) as char,
            layer: format!("{n}x{n},{k}x{k},{ic},{oc},{s},{p}"),
            mloop_gbs: decide::required_bandwidth_gbs(&c, in_shape, cfg, LoopOrder::Mloop),
            kloop_gbs: decide::required_bandwidth_gbs(&c, in_shape, cfg, LoopOrder::Kloop),
        });
    }
    rows
}

pub fn print_fig4(rows: &[Fig4Row], cfg: &SnowflakeConfig) {
    println!("Figure 4: required memory bandwidth in Mloop or Kloop mode");
    println!("(board limit {:.1} GB/s)", cfg.bandwidth_gbs());
    println!("{:<3} {:<24} {:>12} {:>12}", "", "CONV", "Mloop GB/s", "Kloop GB/s");
    for r in rows {
        let mark = |v: f64| if v > cfg.bandwidth_gbs() { " *over*" } else { "" };
        println!(
            "{:<3} {:<24} {:>12.2}{} {:>11.2}{}",
            r.tag,
            r.layer,
            r.mloop_gbs,
            mark(r.mloop_gbs),
            r.kloop_gbs,
            mark(r.kloop_gbs)
        );
    }
}

// ---------------------------------------------------------------------
// §5.3 accuracy: fp32 vs Q8.8 vs Q5.11
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct AccuracyRow {
    pub format: String,
    pub top1_agree: f64,
    pub top5_agree: f64,
}

fn topk(data: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..data.len()).collect();
    idx.sort_by(|&a, &b| data[b].partial_cmp(&data[a]).unwrap());
    idx.truncate(k);
    idx
}

/// A small classification CNN for the quantization-accuracy experiment
/// (the ImageNet substitution, DESIGN.md §Substitutions).
pub fn accuracy_net() -> Graph {
    let mut g = Graph::new("acc_net", Shape::new(3, 32, 32));
    g.push_seq(LayerKind::Conv { in_ch: 3, out_ch: 16, kh: 3, kw: 3, stride: 1, pad: 1, relu: true }, "c1");
    g.push_seq(LayerKind::MaxPool { kh: 2, kw: 2, stride: 2, pad: 0 }, "p1");
    g.push_seq(LayerKind::Conv { in_ch: 16, out_ch: 32, kh: 3, kw: 3, stride: 1, pad: 1, relu: true }, "c2");
    g.push_seq(LayerKind::MaxPool { kh: 2, kw: 2, stride: 2, pad: 0 }, "p2");
    g.push_seq(LayerKind::Conv { in_ch: 32, out_ch: 64, kh: 3, kw: 3, stride: 1, pad: 1, relu: true }, "c3");
    g.push_seq(LayerKind::MaxPool { kh: 2, kw: 2, stride: 2, pad: 0 }, "p3");
    g.push_seq(LayerKind::Fc { in_features: 64 * 4 * 4, out_features: 100, relu: false }, "fc");
    g.validate().unwrap();
    g
}

/// E5: top-1/top-5 *agreement* with the fp32 reference over `n` random
/// inputs, for Q8.8 and Q5.11 — reproducing the paper's ordering
/// (fp32 > Q5.11 > Q8.8 on ImageNet top-5: 89 / 88 / 84 %).
pub fn accuracy(n: usize, seed: u64) -> Vec<AccuracyRow> {
    let g = accuracy_net();
    let w = Weights::init(&g, seed);
    let mut rng = Rng::new(seed ^ 0xacc);
    let mut agree: std::collections::BTreeMap<&str, (usize, usize)> = Default::default();
    for _ in 0..n {
        let mut x = crate::tensor::Tensor::zeros(&[3, 32, 32]);
        for v in x.data.iter_mut() {
            *v = rng.f32_range(-1.0, 1.0);
        }
        let reff = refimpl::forward_f32(&g, &w, &x);
        let logits_f = &reff.last().unwrap().data;
        let t1 = topk(logits_f, 1);
        for (name, fmt) in [("Q8.8", Q8_8), ("Q5.11", Q5_11)] {
            let q = refimpl::forward_q(&g, &w, &x, fmt);
            let logits_q: Vec<f32> = fmt.dequantize_slice(&q.last().unwrap().data);
            let q1 = topk(&logits_q, 1);
            let q5 = topk(&logits_q, 5);
            let e = agree.entry(name).or_insert((0, 0));
            if q1[0] == t1[0] {
                e.0 += 1;
            }
            if q5.contains(&t1[0]) {
                e.1 += 1;
            }
        }
    }
    let mut rows = vec![AccuracyRow {
        format: "float32".into(),
        top1_agree: 1.0,
        top5_agree: 1.0,
    }];
    for (name, fmt) in [("Q5.11", Q5_11), ("Q8.8", Q8_8)] {
        let _ = fmt;
        let (a1, a5) = agree[name];
        rows.push(AccuracyRow {
            format: name.into(),
            top1_agree: a1 as f64 / n as f64,
            top5_agree: a5 as f64 / n as f64,
        });
    }
    rows
}

pub fn print_accuracy(rows: &[AccuracyRow]) {
    println!("Quantization profile (§5.3 substitution): agreement with fp32 on a random CNN");
    println!("{:<10} {:>12} {:>12}", "Format", "top-1 agree", "top-5 agree");
    for r in rows {
        println!("{:<10} {:>11.1}% {:>11.1}%", r.format, r.top1_agree * 100.0, r.top5_agree * 100.0);
    }
}

/// Quantization error (RMS) per format — a finer-grained secondary
/// metric for the accuracy experiment.
pub fn quantization_rms(fmt: QFormat, seed: u64) -> f64 {
    let g = accuracy_net();
    let w = Weights::init(&g, seed);
    let x = synthetic_input(&g, seed);
    let f = refimpl::forward_f32(&g, &w, &x);
    let q = refimpl::forward_q(&g, &w, &x, fmt);
    let a = &f.last().unwrap().data;
    let b = fmt.dequantize_slice(&q.last().unwrap().data);
    let mse: f64 = a
        .iter()
        .zip(&b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64;
    mse.sqrt()
}

// ---------------------------------------------------------------------
// Schedule quality: heuristic vs cost-model vs measured tuning
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ScheduleQualityRow {
    pub model: String,
    /// "heuristic" | "cost-model" | "measured".
    pub mode: &'static str,
    pub cycles: u64,
    pub exec_ms: f64,
    pub bw_gbs: f64,
    pub fps: f64,
}

fn quality_row(
    model: &str,
    mode: &'static str,
    stats: &crate::sim::stats::Stats,
    cfg: &SnowflakeConfig,
) -> ScheduleQualityRow {
    let ms = stats.time_ms(cfg);
    ScheduleQualityRow {
        model: model.to_string(),
        mode,
        cycles: stats.cycles,
        exec_ms: ms,
        bw_gbs: stats.bandwidth_gbs(cfg),
        fps: 1000.0 / ms,
    }
}

/// The tuning experiment: each model end-to-end (FC excluded, as
/// Table 2) under the seed heuristic, the analytical cost-model search,
/// the all-Kloop force (the pre-Mloop/rotation ceiling the CI gate
/// compares against), and measured tuning. The compile-and-run legs fan
/// out through the parallel sweep harness; the measured leg runs its
/// own full-model trials internally ([`tune::tune_measured`]).
pub fn schedule_quality(
    cfg: &SnowflakeConfig,
    models: &[&str],
    seed: u64,
    top_k: usize,
) -> Vec<ScheduleQualityRow> {
    const MODES: [(&str, TuneMode, Option<LoopOrder>); 3] = [
        ("heuristic", TuneMode::Heuristic, None),
        ("cost-model", TuneMode::Analytical, None),
        ("forced-kloop", TuneMode::Analytical, Some(LoopOrder::Kloop)),
    ];
    let mut jobs = Vec::new();
    for name in models {
        let g = zoo::by_name(name).unwrap_or_else(|| panic!("unknown model {name}"));
        for (mode, tune, force) in MODES {
            let opts = CompileOptions {
                skip_fc: true,
                tune,
                force_loop_order: force,
                ..Default::default()
            };
            jobs.push(SweepJob::new(format!("sq/{name}/{mode}"), g.clone(), cfg, opts).seed(seed));
        }
    }
    let outs = sweep::run_sweep_strict(&jobs, None);

    let mut rows = Vec::new();
    for (i, name) in models.iter().enumerate() {
        for (j, (mode, _, _)) in MODES.iter().enumerate() {
            rows.push(quality_row(name, mode, &outs[i * MODES.len() + j].stats, cfg));
        }
        let g = zoo::by_name(name).unwrap();
        let base = CompileOptions { skip_fc: true, ..Default::default() };
        let tuned = tune::tune_measured(&g, cfg, &base, seed, top_k)
            .unwrap_or_else(|e| panic!("measured tuning of {name} failed: {e}"));
        rows.push(quality_row(name, "measured", &tuned.outcome.stats, cfg));
    }
    rows
}

pub fn print_schedule_quality(rows: &[ScheduleQualityRow]) {
    println!("Schedule quality: heuristic vs cost-model vs measured tuning (FC excluded)");
    println!(
        "{:<12} {:<11} {:>12} {:>10} {:>10} {:>8}",
        "Model", "Tuning", "Cycles", "Time [ms]", "BW [GB/s]", "fps"
    );
    for r in rows {
        println!(
            "{:<12} {:<11} {:>12} {:>10.3} {:>10.2} {:>8.1}",
            r.model, r.mode, r.cycles, r.exec_ms, r.bw_gbs, r.fps
        );
    }
}

// ---------------------------------------------------------------------
// Predicted-vs-measured cycle error per conv layer
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct PredictionErrorRow {
    pub layer: String,
    pub predicted: u64,
    pub measured: u64,
    /// predicted / measured.
    pub ratio: f64,
}

/// Run every distinct conv shape of a model standalone and compare the
/// analytical model's predicted cycles against the event core.
///
/// Standalone graphs are bypass-free, so the cost model's fused-bypass
/// terms (bypass strip traffic/streams, the per-window bypass VMOV)
/// are *not* covered by this gate — residual layers are Kloop-only by
/// construction and their rows/split choices are verified in
/// simulation by the measured tuner instead.
pub fn prediction_error(
    cfg: &SnowflakeConfig,
    model: &str,
    seed: u64,
) -> Vec<PredictionErrorRow> {
    let g = zoo::by_name(model).unwrap_or_else(|| panic!("unknown model {model}"));
    let shapes = g.shapes();
    let mut seen = std::collections::BTreeSet::new();
    let mut rows = Vec::new();
    for n in &g.nodes {
        let LayerKind::Conv { in_ch, out_ch, kh, kw, stride, pad, relu } = n.kind else {
            continue;
        };
        let in_shape = n.inputs.first().map(|&p| shapes[p]).unwrap_or(g.input);
        let name = format!(
            "{}x{},{}x{},{}->{},s{},p{}",
            in_shape.h, in_shape.w, kh, kw, in_ch, out_ch, stride, pad
        );
        if !seen.insert(name.clone()) {
            continue;
        }
        let mut lg = Graph::new(&name, in_shape);
        lg.push_seq(LayerKind::Conv { in_ch, out_ch, kh, kw, stride, pad, relu }, "c");
        let out = driver::run_model(&lg, cfg, &CompileOptions::default(), seed)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let decide::OpPlan::Conv(d) = &out.compiled.plan.layers[0].decision else {
            unreachable!()
        };
        let predicted = d.predicted.cycles;
        let measured = out.stats.cycles.max(1);
        rows.push(PredictionErrorRow {
            layer: name,
            predicted,
            measured,
            ratio: predicted as f64 / measured as f64,
        });
    }
    rows
}

pub fn print_prediction_error(model: &str, rows: &[PredictionErrorRow]) {
    println!(
        "{model}: analytical model vs event core per conv layer (bound: {:.1}x either way)",
        crate::compiler::cost::MODEL_ERROR_BOUND
    );
    println!("{:<28} {:>12} {:>12} {:>8}", "Layer", "Predicted", "Measured", "Ratio");
    for r in rows {
        println!("{:<28} {:>12} {:>12} {:>8.2}", r.layer, r.predicted, r.measured, r.ratio);
    }
}

// ---------------------------------------------------------------------
// `repro explain`: the chosen per-layer schedules
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ExplainRow {
    pub node: usize,
    pub kind: String,
    pub schedule: String,
    pub predicted: String,
    /// Banked-rotation diagnosis: empty unless the rotation skeleton was
    /// a live option at the chosen tile height (then: kernel-set shape,
    /// prefetch distance, per-pass bank phase, and predicted cycles next
    /// to the resident-Mloop alternative).
    pub rotation: String,
}

/// Compile a model and describe every layer's chosen schedule — the
/// debugging view of tuner decisions. Conv layers where the banked
/// rotation was considered additionally report the rotation's bank
/// phase per pass, its prefetch distance, and its predicted cycles
/// against the resident-Mloop alternative (ISSUE 5 satellite).
pub fn explain(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> Result<Vec<ExplainRow>, String> {
    let artifact = crate::compiler::Compiler::new(cfg.clone())
        .options(opts.clone())
        .build(g)
        .map_err(|e| e.to_string())?;
    let mut rows = Vec::new();
    for lp in &artifact.compiled.plan.layers {
        let node = lp.op.out_node();
        let kind = lp.op.name().to_string();
        let mut rotation = String::new();
        let (schedule, predicted) = match &lp.decision {
            decide::OpPlan::Conv(d) => {
                let policy = match d.policy {
                    BalancePolicy::Greedy { .. } => "greedy".to_string(),
                    BalancePolicy::TwoUnits => "two-units".to_string(),
                    BalancePolicy::OneUnit => "one-unit".to_string(),
                };
                if let Some((_, gx)) = tune::conv_geom_for(&artifact.compiled.plan, lp) {
                    if cost::mloop_rot_viable(&gx, cfg, d.rows_per_cu, d.split) {
                        let (gset, passes) = cost::rot_sets(d.kernel_words, d.k_groups, cfg);
                        let rot = cost::estimate(
                            &gx,
                            &cost::Schedule {
                                order: LoopOrder::MloopRot,
                                rows_per_cu: d.rows_per_cu,
                                policy: d.policy,
                            },
                            cfg,
                            opts.smart_delay_slots,
                        );
                        let resident = if cost::mloop_viable(&gx, cfg, d.rows_per_cu) {
                            let m = cost::estimate(
                                &gx,
                                &cost::Schedule {
                                    order: LoopOrder::Mloop,
                                    rows_per_cu: d.rows_per_cu,
                                    policy: d.policy,
                                },
                                cfg,
                                opts.smart_delay_slots,
                            );
                            format!("~{} cyc", m.cycles)
                        } else {
                            "n/a".to_string()
                        };
                        let shown = passes.min(4);
                        let phases: Vec<String> = (0..shown)
                            .map(|p| ((p * d.n_tiles) % cfg.mbuf_banks).to_string())
                            .collect();
                        rotation = format!(
                            "rotation: sets {gset}x{passes}, pf-dist {}, bank phase/pass [{}{}], \
                             pred ~{} cyc vs resident-Mloop {}",
                            cfg.mbuf_banks - 1,
                            phases.join(","),
                            if passes > shown { ",…" } else { "" },
                            rot.cycles,
                            resident
                        );
                    }
                }
                (
                    format!(
                        "{:?} rows={}(cap {}) tiles={} split={} {policy}",
                        d.order, d.rows_per_cu, d.max_rows, d.n_tiles, d.split
                    ),
                    format!(
                        "~{} cyc, {:.2} MB ({} streams)",
                        d.predicted.cycles,
                        d.predicted.dram_bytes as f64 / 1e6,
                        d.predicted.streams
                    ),
                )
            }
            decide::OpPlan::MaxPool(p) => (
                format!("rows={}(cap {}) tiles={}", p.rows_per_cu, p.max_rows, p.n_tiles),
                format!("~{} cyc, {:.2} MB", p.predicted.cycles, p.predicted.dram_bytes as f64 / 1e6),
            ),
            decide::OpPlan::AvgPool(p) => (format!("chunks={}", p.chunks), String::new()),
            decide::OpPlan::Fc(f) => (
                format!("k_groups={} chunks={}", f.k_groups, f.chunks.len()),
                String::new(),
            ),
        };
        rows.push(ExplainRow { node, kind, schedule, predicted, rotation });
    }
    Ok(rows)
}

pub fn print_explain(model: &str, rows: &[ExplainRow]) {
    println!("{model}: chosen per-layer schedules");
    println!("{:<5} {:<9} {:<44} {}", "node", "kind", "schedule", "predicted");
    for r in rows {
        println!("{:<5} {:<9} {:<44} {}", r.node, r.kind, r.schedule, r.predicted);
        if !r.rotation.is_empty() {
            println!("{:<5} {:<9} {}", "", "", r.rotation);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_job_manifest() {
        // 4 layers x (hand, auto) + 2 models (fast) + 5 policies + 8
        // ablation variants, with stable name prefixes for splitting.
        let cfg = SnowflakeConfig::default();
        let t1 = table1_jobs(&cfg, 1);
        assert_eq!(t1.len(), 8);
        assert!(t1[0].name.starts_with("table1/") && t1[0].name.ends_with("/hand"));
        assert!(t1[1].name.ends_with("/auto"));
        assert_eq!(table2_jobs(&cfg, &["alexnet", "resnet18"], 1).len(), 2);
        assert_eq!(table3_jobs(&cfg, 1).len(), 5);
        let ab = ablation_jobs(&cfg, 1);
        assert_eq!(ab.len(), 8);
        assert!(ab[0].name.contains("baseline"));
    }

    #[test]
    fn table1_via_sweep_matches_direct_runs() {
        // The sweep-backed table must agree with straight-line driver
        // runs (same seeds, deterministic simulation).
        let cfg = SnowflakeConfig::default();
        let g = &zoo::table1_layers()[0];
        let rows = table1(&cfg, 3);
        let auto = crate::coordinator::driver::run_model(
            g,
            &cfg,
            &CompileOptions::default(),
            3,
        )
        .unwrap();
        assert_eq!(rows[0].layer, g.name);
        assert!((rows[0].auto_ms - auto.stats.time_ms(&cfg)).abs() < 1e-12);
        assert_eq!(rows[0].auto_instrs, auto.compiled.code_len);
    }

    #[test]
    fn fig4_shape_holds() {
        let cfg = SnowflakeConfig::default();
        let rows = fig4(&cfg);
        assert_eq!(rows.len(), 8);
        // A and B (AlexNet) stay under the limit in both modes.
        for r in &rows[..2] {
            assert!(r.kloop_gbs < cfg.bandwidth_gbs(), "{}: {}", r.tag, r.kloop_gbs);
        }
        // G exceeds the limit in Mloop but not (or less) in Kloop.
        let g = &rows[6];
        assert!(g.mloop_gbs > cfg.bandwidth_gbs(), "G mloop {}", g.mloop_gbs);
        assert!(g.kloop_gbs < g.mloop_gbs, "G kloop {} !< mloop {}", g.kloop_gbs, g.mloop_gbs);
    }

    #[test]
    fn explain_lists_conv_schedules() {
        let cfg = SnowflakeConfig::default();
        let rows = explain(&ablation_layer(), &cfg, &CompileOptions::default()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].kind, "conv");
        assert!(rows[0].schedule.contains("rows="), "{}", rows[0].schedule);
        assert!(rows[0].schedule.contains("split="), "{}", rows[0].schedule);
        assert!(rows[0].predicted.contains("cyc"), "{}", rows[0].predicted);
    }

    #[test]
    fn prediction_error_rows_are_sane() {
        // One cheap standalone layer through the full predicted-vs-
        // measured path: ratios positive and within the documented
        // bound (the full per-model table runs in benches/tuning.rs).
        let cfg = SnowflakeConfig::default();
        let rows = prediction_error(&cfg, "alexnet", 11);
        assert!(rows.len() >= 5, "alexnet has at least 5 distinct conv shapes");
        for r in &rows {
            assert!(r.predicted > 0 && r.measured > 0, "{:?}", r);
            assert!(r.ratio > 0.0);
        }
    }

    #[test]
    fn quantization_rms_ordering() {
        let r88 = quantization_rms(Q8_8, 5);
        let r511 = quantization_rms(Q5_11, 5);
        assert!(r511 < r88, "Q5.11 rms {r511} !< Q8.8 rms {r88}");
    }

    #[test]
    fn accuracy_ordering_holds() {
        let rows = accuracy(16, 3);
        assert_eq!(rows[0].format, "float32");
        let q511 = rows.iter().find(|r| r.format == "Q5.11").unwrap();
        let q88 = rows.iter().find(|r| r.format == "Q8.8").unwrap();
        assert!(q511.top5_agree >= q88.top5_agree);
        assert!(q511.top5_agree > 0.5);
    }
}
