//! Parallel sweep harness: fan independent (model × config × options ×
//! balance-policy) simulations across OS threads, so a whole paper grid
//! (Tables 1–3 + ablations) runs in one invocation at host-core
//! throughput.
//!
//! Jobs are plain data — a graph, a hardware config, compiler options,
//! a seed and a frame count — and every job is executed through
//! [`crate::coordinator::driver::run_batch`], i.e. compiled once and
//! simulated with the event-driven core. Results come back in job
//! order regardless of which thread ran them, and each job's outcome
//! is deterministic (fixed seeds, data-independent timing), so a
//! parallel sweep is bit-identical to a serial one.
//!
//! Implementation note: this uses `std::thread::scope` with an atomic
//! work index instead of `rayon` because the default build must stay
//! dependency-free for fully offline environments (no registry access;
//! see rust/Cargo.toml).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use super::driver;
use crate::arch::SnowflakeConfig;
use crate::compiler::CompileOptions;
use crate::model::graph::Graph;
use crate::sim::stats::Stats;

/// One independent simulation of the sweep.
pub struct SweepJob {
    /// Identifier the caller uses to pick results out of the sweep
    /// (e.g. "table1/conv2/hand").
    pub name: String,
    pub graph: Graph,
    pub cfg: SnowflakeConfig,
    pub opts: CompileOptions,
    pub seed: u64,
    /// Inference frames through one deployment (batched inference).
    pub frames: usize,
}

impl SweepJob {
    pub fn new(
        name: impl Into<String>,
        graph: Graph,
        cfg: &SnowflakeConfig,
        opts: CompileOptions,
    ) -> Self {
        SweepJob { name: name.into(), graph, cfg: cfg.clone(), opts, seed: 42, frames: 1 }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn frames(mut self, frames: usize) -> Self {
        self.frames = frames.max(1);
        self
    }
}

/// What one job produced.
pub struct SweepOutcome {
    pub name: String,
    /// First frame's full statistics (frames are deterministic).
    pub stats: Stats,
    pub per_frame_cycles: Vec<u64>,
    /// Generated instruction count before bank padding.
    pub code_len: usize,
    /// Deployment footprint in memory words.
    pub plan_words: usize,
    /// Host wall time for compile + all frames.
    pub wall: Duration,
}

pub type SweepResult = Result<SweepOutcome, String>;

/// Worker threads used when the caller does not pin a count.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The worker count [`run_sweep`] will actually use for `jobs` jobs:
/// requested (or one per host core), never more than there are jobs.
pub fn resolve_threads(jobs: usize, threads: Option<usize>) -> usize {
    threads.unwrap_or_else(default_threads).clamp(1, jobs.max(1))
}

fn execute(job: &SweepJob) -> SweepResult {
    let t0 = Instant::now();
    let out = driver::run_batch(&job.graph, &job.cfg, &job.opts, job.seed, job.frames.max(1))
        .map_err(|e| format!("{}: {e}", job.name))?;
    Ok(SweepOutcome {
        name: job.name.clone(),
        per_frame_cycles: out.per_frame.iter().map(|s| s.cycles).collect(),
        stats: out.per_frame[0].clone(),
        code_len: out.compiled.code_len,
        plan_words: out.compiled.plan.mem_words,
        wall: t0.elapsed(),
    })
}

/// Run every job, `threads` at a time (default: one per host core).
/// Results are returned in job order; a failed compile or simulation
/// yields `Err` for that job without disturbing the others.
pub fn run_sweep(jobs: &[SweepJob], threads: Option<usize>) -> Vec<SweepResult> {
    if jobs.is_empty() {
        return Vec::new();
    }
    let n = resolve_threads(jobs.len(), threads);
    if n == 1 {
        return jobs.iter().map(execute).collect();
    }
    let next = AtomicUsize::new(0);
    let mut results: Vec<Option<SweepResult>> = (0..jobs.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let next = &next;
                s.spawn(move || {
                    let mut mine: Vec<(usize, SweepResult)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        mine.push((i, execute(&jobs[i])));
                    }
                    mine
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("sweep worker panicked") {
                results[i] = Some(r);
            }
        }
    });
    results.into_iter().map(|r| r.expect("every job claimed exactly once")).collect()
}

/// Convenience: run and unwrap, panicking on the first failed job
/// (bench/table paths where any failure is fatal anyway).
pub fn run_sweep_strict(jobs: &[SweepJob], threads: Option<usize>) -> Vec<SweepOutcome> {
    run_sweep(jobs, threads)
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("sweep job failed: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{LayerKind, Shape};

    fn conv_graph(name: &str, out_ch: usize) -> Graph {
        let mut g = Graph::new(name, Shape::new(16, 10, 10));
        g.push_seq(
            LayerKind::Conv { in_ch: 16, out_ch, kh: 3, kw: 3, stride: 1, pad: 1, relu: true },
            "c",
        );
        g
    }

    #[test]
    fn parallel_matches_serial() {
        let cfg = SnowflakeConfig::default();
        let jobs: Vec<SweepJob> = (0..6)
            .map(|i| {
                SweepJob::new(
                    format!("j{i}"),
                    conv_graph(&format!("g{i}"), 4 + 4 * (i % 3)),
                    &cfg,
                    CompileOptions::default(),
                )
                .seed(100 + i as u64)
            })
            .collect();
        let serial = run_sweep_strict(&jobs, Some(1));
        let parallel = run_sweep_strict(&jobs, Some(4));
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.name, p.name, "ordering must be preserved");
            assert_eq!(s.stats.comparable(), p.stats.comparable(), "{}", s.name);
        }
    }

    #[test]
    fn failed_job_is_isolated() {
        let cfg = SnowflakeConfig::default();
        // out_ch that is valid next to a graph with an invalid shape
        // (too few output rows for 4 CUs -> compile error).
        let mut bad = Graph::new("bad", Shape::new(8, 4, 4));
        bad.push_seq(
            LayerKind::Conv { in_ch: 8, out_ch: 8, kh: 3, kw: 3, stride: 2, pad: 0, relu: false },
            "c",
        );
        let jobs = vec![
            SweepJob::new("ok", conv_graph("g", 8), &cfg, CompileOptions::default()),
            SweepJob::new("bad", bad, &cfg, CompileOptions::default()),
        ];
        let results = run_sweep(&jobs, Some(2));
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
    }
}
