//! Bench E4 — regenerates Figure 4: required memory bandwidth in Mloop
//! vs Kloop mode for eight conv examples against the 4.2 GB/s board
//! budget.

use snowflake::arch::SnowflakeConfig;
use snowflake::coordinator::report;
use snowflake::util::bench::Bencher;

fn main() {
    let cfg = SnowflakeConfig::default();
    let rows = report::fig4(&cfg);
    report::print_fig4(&rows, &cfg);

    // Shape: AlexNet layers (A, B) under the line in both modes; the big
    // ResNet50 layers (G, H) demand more than the budget under Mloop and
    // do no worse under Kloop — "Kloop mode is necessary for those
    // layers" (§6.2).
    for r in &rows[..2] {
        assert!(r.mloop_gbs.min(r.kloop_gbs) < cfg.bandwidth_gbs(), "{}", r.tag);
    }
    for r in &rows[6..] {
        assert!(r.mloop_gbs > cfg.bandwidth_gbs(), "{} mloop {}", r.tag, r.mloop_gbs);
        assert!(r.kloop_gbs <= r.mloop_gbs, "{}", r.tag);
    }

    let b = Bencher::quick();
    b.run("fig4/model", || {
        let _ = report::fig4(&cfg);
    });
}
