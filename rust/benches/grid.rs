//! Bench — the full paper grid in one invocation: Tables 1–3 plus the
//! ablation variants, fanned across host threads by the parallel sweep
//! harness (`snowflake::coordinator::sweep`). `--fast` drops ResNet50.

use snowflake::arch::SnowflakeConfig;
use snowflake::coordinator::report;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let cfg = SnowflakeConfig::default();
    let grid = report::run_grid(&cfg, 42, fast, None);
    report::print_grid(&grid);

    // Shape assertions pooled from the per-table benches, so one grid
    // run exercises the whole set.
    for r in &grid.table1 {
        let ratio = r.auto_ms / r.hand_ms;
        assert!(ratio < 1.15, "{}: auto within 15% of hand ({ratio})", r.layer);
        assert!(r.auto_instrs >= r.hand_instrs, "{}", r.layer);
    }
    let t = |name: &str| grid.table2.iter().find(|r| r.model.contains(name)).map(|r| r.exec_ms);
    if let (Some(a), Some(r18)) = (t("alexnet"), t("resnet18")) {
        assert!(a < r18, "AlexNet must be faster than ResNet18");
    }
    let best = grid.table3.iter().map(|r| r.speedup).fold(0.0f64, f64::max);
    let worst_imb = grid.table3.iter().map(|r| r.imbalance_pct).fold(0.0f64, f64::max);
    assert!(best > 1.1, "fine balance must beat the worst case ({best})");
    assert!(worst_imb > 50.0, "degenerate policies must show heavy imbalance");
    assert!(!grid.ablations.is_empty());
    println!("\ngrid OK: {} jobs verified", grid.jobs);
}
