//! Ablation bench — the design choices DESIGN.md calls out, each toggled
//! in isolation on an AlexNet-conv2-class layer:
//!   * delay-slot filling (the Table 1 hand/auto axis),
//!   * maps-load splitting (the §6.3 balance knob),
//!   * vector-queue depth (the "16 vector instructions" trace buffer),
//!   * DMA setup cost (why fine-grained loads must be balanced, not
//!     merely scattered),
//!   * memory-region reuse (step-2 dependency labels).

use snowflake::arch::SnowflakeConfig;
use snowflake::compiler::{compile, BalancePolicy, CompileOptions};
use snowflake::coordinator::driver::run_model;
use snowflake::model::graph::Graph;
use snowflake::model::layer::{LayerKind, Shape};
use snowflake::model::zoo;

fn layer() -> Graph {
    let mut g = Graph::new("27x27,5x5,64,192,1,2", Shape::new(64, 27, 27));
    g.push_seq(
        LayerKind::Conv { in_ch: 64, out_ch: 192, kh: 5, kw: 5, stride: 1, pad: 2, relu: true },
        "conv2",
    );
    g
}

fn run(cfg: &SnowflakeConfig, opts: &CompileOptions) -> (f64, usize) {
    let out = run_model(&layer(), cfg, opts, 42).expect("run");
    (out.stats.time_ms(cfg), out.compiled.code_len)
}

fn main() {
    let cfg = SnowflakeConfig::default();
    let base = CompileOptions::default();
    println!("{:<34} {:>10} {:>8}", "variant", "time [ms]", "instrs");

    let (t0, i0) = run(&cfg, &base);
    println!("{:<34} {:>10.3} {:>8}", "baseline (auto, greedy/2)", t0, i0);

    let (t, i) = run(&cfg, &CompileOptions { smart_delay_slots: true, ..base.clone() });
    println!("{:<34} {:>10.3} {:>8}", "smart delay slots (hand)", t, i);
    assert!(i <= i0);

    for split in [1usize, 4] {
        let (t, i) = run(
            &cfg,
            &CompileOptions { balance: BalancePolicy::Greedy { split }, ..base.clone() },
        );
        println!("{:<34} {:>10.3} {:>8}", format!("maps-load split = {split}"), t, i);
    }

    for depth in [4usize, 32] {
        let c = SnowflakeConfig { vector_queue_depth: depth, ..cfg.clone() };
        let (t, i) = run(&c, &base);
        println!("{:<34} {:>10.3} {:>8}", format!("vector queue depth = {depth}"), t, i);
    }

    for setup in [8u64, 256] {
        let c = SnowflakeConfig { dma_setup_cycles: setup, ..cfg.clone() };
        let (t, i) = run(&c, &base);
        println!("{:<34} {:>10.3} {:>8}", format!("dma setup = {setup} cycles"), t, i);
    }

    // Region reuse: whole-model memory footprint (AlexNet).
    let g = zoo::alexnet_owt();
    let no = compile(&g, &cfg, &CompileOptions { skip_fc: true, ..base.clone() }).unwrap();
    let yes = compile(
        &g,
        &cfg,
        &CompileOptions { skip_fc: true, reuse_regions: true, ..base },
    )
    .unwrap();
    println!(
        "\nregion reuse (alexnet plan): {:.1} MB -> {:.1} MB",
        no.plan.mem_words as f64 * 2.0 / 1e6,
        yes.plan.mem_words as f64 * 2.0 / 1e6
    );
    assert!(yes.plan.mem_words < no.plan.mem_words);
}
