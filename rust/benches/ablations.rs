//! Ablation bench — the design choices DESIGN.md calls out, each toggled
//! in isolation on an AlexNet-conv2-class layer:
//!   * delay-slot filling (the Table 1 hand/auto axis),
//!   * maps-load splitting (the §6.3 balance knob),
//!   * vector-queue depth (the "16 vector instructions" trace buffer),
//!   * DMA setup cost (why fine-grained loads must be balanced, not
//!     merely scattered),
//!   * memory-region reuse (step-2 dependency labels).
//!
//! The simulation variants run as one parallel sweep
//! (`snowflake::coordinator::sweep`); the region-reuse comparison is
//! compile-only and stays serial.

use snowflake::arch::SnowflakeConfig;
use snowflake::compiler::{CompileOptions, Compiler};
use snowflake::coordinator::report;
use snowflake::coordinator::sweep::run_sweep_strict;
use snowflake::model::zoo;
use snowflake::model::graph::Graph;

/// Build through the `Compiler` front door; these tests only need the
/// compiled model, not the full artifact.
fn compile(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> Result<snowflake::compiler::CompiledModel, snowflake::compiler::CompileError> {
    Compiler::new(cfg.clone()).options(opts.clone()).compile(g)
}

fn main() {
    let cfg = SnowflakeConfig::default();
    let jobs = report::ablation_jobs(&cfg, 42);
    let t0 = std::time::Instant::now();
    let outs = run_sweep_strict(&jobs, None);
    println!("{:<34} {:>10} {:>8}", "variant", "time [ms]", "instrs");
    for o in &outs {
        println!(
            "{:<34} {:>10.3} {:>8}",
            o.name.strip_prefix("ablate/").unwrap_or(&o.name),
            o.stats.time_ms(&cfg),
            o.code_len
        );
    }
    println!("({} variants swept in {:?})", outs.len(), t0.elapsed());

    // Shape checks mirror the old serial bench: smart delay slots never
    // add instructions over the baseline.
    let baseline = &outs[0];
    let smart = outs.iter().find(|o| o.name.contains("smart delay")).expect("smart variant");
    assert!(smart.code_len <= baseline.code_len);

    // Region reuse: whole-model memory footprint (AlexNet), compile-only.
    let base = CompileOptions::default();
    let g = zoo::alexnet_owt();
    let no = compile(&g, &cfg, &CompileOptions { skip_fc: true, ..base.clone() }).unwrap();
    let yes = compile(
        &g,
        &cfg,
        &CompileOptions { skip_fc: true, reuse_regions: true, ..base },
    )
    .unwrap();
    println!(
        "\nregion reuse (alexnet plan): {:.1} MB -> {:.1} MB",
        no.plan.mem_words as f64 * 2.0 / 1e6,
        yes.plan.mem_words as f64 * 2.0 / 1e6
    );
    assert!(yes.plan.mem_words < no.plan.mem_words);
}
