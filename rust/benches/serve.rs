//! Bench — serving-runtime throughput (E17): AlexNet + ResNet18
//! resident, a mixed request stream through the worker pool at 1 and N
//! workers versus the sequential `Engine::infer` loop.
//!
//! Doubles as a differential check: every served request's simulated
//! cycle count must equal the sequential path's for that model — the
//! worker pool, batch coalescing and the artifact cache may only
//! change *host* wall time, never a simulated number. Host throughput
//! is printed but not gated (shared runners are too noisy); the bit-
//! identity assertion is the gate.
//!
//! Since ISSUE 6 this also pins the zero-overhead-when-off contract:
//! the server below runs with an explicit default `ResilienceConfig`
//! (no faults, no deadline), and the bit-identity assertion proves the
//! resilience plumbing — per-attempt fault-plan lookups, the deadline
//! check, worker supervision — costs nothing in simulated time when
//! disabled. The report must come back with every resilience counter
//! at zero.
//!
//! Since ISSUE 7 the same contract covers scheduling: the default
//! `SchedConfig`/`AdmissionConfig` are asserted inactive, and a second
//! multi-worker pass with WFQ *on* must still produce cycle counts
//! bit-identical to the sequential path — fair queueing reorders
//! dispatch, never simulated numbers.

use std::time::Instant;

use snowflake::arch::SnowflakeConfig;
use snowflake::compiler::{partition, Artifact, ArtifactFormat, CompileOptions, Compiler};
use snowflake::engine::cluster::{self, Cluster};
use snowflake::engine::serve::{
    AdmissionConfig, ResilienceConfig, SchedConfig, ServeConfig, Server,
};
use snowflake::engine::Engine;
use snowflake::model::weights::synthetic_input;
use snowflake::model::zoo;

const REQUESTS: usize = 12;

fn build(cfg: &SnowflakeConfig, name: &str) -> Artifact {
    let g = zoo::by_name(name).expect("zoo model");
    let opts = CompileOptions { skip_fc: true, ..Default::default() };
    Compiler::new(cfg.clone()).options(opts).build(&g).expect("build")
}

fn main() {
    let cfg = SnowflakeConfig::default();
    let seed = 42;
    // The scheduling and admission policies must be off by default —
    // the FIFO passes below exercise exactly the off-state.
    assert!(!SchedConfig::default().active(), "default SchedConfig is not off");
    assert!(!AdmissionConfig::default().active(), "default AdmissionConfig is not off");
    let artifacts = [build(&cfg, "alexnet"), build(&cfg, "resnet18")];
    let graphs: Vec<_> = artifacts.iter().map(|a| a.graph.clone()).collect();

    // Sequential baseline: one engine, requests served in order.
    let mut engine = Engine::new(cfg.clone());
    let handles: Vec<_> = artifacts
        .iter()
        .map(|a| engine.load(a.clone(), seed).expect("load"))
        .collect();
    let t0 = Instant::now();
    let mut seq_cycles = Vec::with_capacity(REQUESTS);
    for r in 0..REQUESTS {
        let m = r % graphs.len();
        let x = synthetic_input(&graphs[m], seed + r as u64);
        seq_cycles.push(engine.infer(handles[m], &x).expect("infer").stats.cycles);
    }
    let seq_wall = t0.elapsed();
    println!(
        "serve bench: {REQUESTS} requests (alexnet/resnet18 alternating), sequential {:.2?} \
         ({:.1} req/s)",
        seq_wall,
        REQUESTS as f64 / seq_wall.as_secs_f64().max(1e-9)
    );

    let workers_max = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    for (workers, wfq) in [(1, false), (workers_max, false), (workers_max, true)] {
        let mut server = Server::new(
            cfg.clone(),
            ServeConfig { workers, max_batch: 3, queue_depth: REQUESTS, cache_cap: 0 },
        );
        // Explicitly the off-state: the cycle assertions below gate the
        // zero-overhead-when-off contract. The third pass turns WFQ on
        // to pin that fair queueing only reorders dispatch — per-request
        // simulated cycles stay bit-identical to the sequential path.
        server.set_resilience(ResilienceConfig::default());
        if wfq {
            server.set_sched(SchedConfig { wfq: true, ..Default::default() });
        }
        let ids: Vec<_> = artifacts
            .iter()
            .map(|a| server.register(a.clone(), seed).expect("register"))
            .collect();
        let requests: Vec<_> = (0..REQUESTS)
            .map(|r| {
                let m = r % graphs.len();
                (ids[m], synthetic_input(&graphs[m], seed + r as u64))
            })
            .collect();
        let (responses, report) = server.serve_all(requests).expect("serve");
        for (r, resp) in responses.iter().enumerate() {
            assert_eq!(
                resp.stats.cycles, seq_cycles[r],
                "request {r}: served cycles diverged from the sequential path at {workers} workers"
            );
        }
        assert_eq!(report.failed(), 0, "healthy run reported failures");
        assert_eq!(report.retries(), 0, "healthy run reported retries");
        assert_eq!(report.faults_injected(), 0, "healthy run reported injected faults");
        assert_eq!(report.workers_replaced(), 0, "healthy run replaced a worker");
        assert!(!report.prefilled_overflow, "{REQUESTS} prefilled requests fit the queue");
        let speedup = seq_wall.as_secs_f64() / report.wall.as_secs_f64().max(1e-9);
        println!(
            "  {workers} worker(s){}: {:.2?} ({:.1} req/s, {speedup:.2}x vs sequential), \
             cache {} hits / {} misses",
            if wfq { " [wfq]" } else { "" },
            report.wall,
            report.requests_per_sec(),
            report.cache.hits,
            report.cache.misses
        );
        for ms in &report.per_model {
            println!(
                "    {:<10} {} requests, avg batch {:.2}, avg queue wait {:.2?}",
                ms.name,
                ms.requests,
                ms.avg_batch(),
                ms.avg_queue_wait()
            );
        }
    }
    println!("serve bench OK: all served cycle counts bit-identical to sequential (FIFO and WFQ)");

    // ---- shard scaling (ISSUE 8) -------------------------------------
    // ResNet18 partitioned into 1..=3 pipeline stages: one real cluster
    // inference per shard count yields the *measured* per-stage cycles,
    // and `pipeline_timing` turns those into steady-state pipeline
    // throughput in virtual time. Gates: every shard count produces the
    // same output words as the unsharded pipeline, and 2 shards must
    // sustain >= 1.5x the 1-shard steady-state throughput.
    let g = zoo::by_name("resnet18").expect("zoo model");
    let opts = CompileOptions { skip_fc: true, ..Default::default() };
    let batch = 16u64;
    println!("shard scaling: resnet18, {batch} requests, virtual time");
    let mut baseline: Option<(u64, snowflake::tensor::Tensor<i16>)> = None;
    for n in 1usize..=3 {
        let plan = partition::partition(&g, &cfg, &opts, n).expect("partition");
        let mut cl = Cluster::new(&plan, seed).expect("cluster");
        let x = synthetic_input(&g, seed);
        let ci = cl.infer(&x).expect("cluster infer");
        let t = cluster::pipeline_timing(cl.last_stage_cycles(), cl.link_cycles(), batch);
        let tput = batch as f64 * cfg.clock_mhz * 1e3 / t.makespan.max(1) as f64;
        println!(
            "  {n} shard(s): cuts {:?}, makespan {:>12} cyc, {:>8.1} req/s steady-state \
             ({:.2}x pipeline speedup)",
            plan.cuts(),
            t.makespan,
            tput,
            t.speedup()
        );
        match &baseline {
            None => baseline = Some((t.makespan, ci.output.clone())),
            Some((mk1, out1)) => {
                assert_eq!(
                    ci.output.count_diff(out1),
                    0,
                    "{n}-shard pipeline output diverged from the single machine"
                );
                if n == 2 {
                    let scale = *mk1 as f64 / t.makespan.max(1) as f64;
                    assert!(
                        scale >= 1.5,
                        "2-shard steady-state throughput is only {scale:.2}x the single \
                         machine (gate: >= 1.5x)"
                    );
                    println!("  shard gate OK: 2 shards sustain {scale:.2}x 1-shard throughput");
                }
            }
        }
    }
    println!("serve bench OK: sharded pipelines bit-identical, 2-shard scaling gate passed");

    // ---- cold start (ISSUE 9) ----------------------------------------
    // Cold path to first response, per encoding: artifact bytes on
    // disk, load time (sniff + decode + every integrity check), deploy
    // time (weights init + DRAM image build), and the first response's
    // simulated cycles. Two gates: the binary envelope is at least 5x
    // smaller than the JSON rendering of the same artifact, and the
    // binary-loaded twin's first response is cycle-identical to the
    // JSON-loaded one — the envelope may only ever change host-side
    // numbers, never simulated ones.
    println!("cold start: artifact load -> deploy -> first response, json vs bin");
    println!(
        "  {:<10} {:>4} {:>10} {:>10} {:>10} {:>14}",
        "model", "fmt", "bytes", "load us", "deploy us", "first cycles"
    );
    for a in &artifacts {
        let name = a.graph.name.clone();
        let mut first_cycles: Option<u64> = None;
        let mut sizes = [0usize; 2];
        for (fi, fmt) in [ArtifactFormat::Json, ArtifactFormat::Bin].into_iter().enumerate() {
            let path = std::env::temp_dir()
                .join(format!(
                    "snowflake_bench_cold_{name}_{}.artifact.{}",
                    std::process::id(),
                    fmt.extension()
                ))
                .to_string_lossy()
                .into_owned();
            a.save_format(&path, fmt).expect("save");
            let bytes = std::fs::metadata(&path).expect("metadata").len() as usize;
            sizes[fi] = bytes;
            let t0 = Instant::now();
            let loaded = Artifact::load(&path, &cfg).expect("load");
            let load_us = t0.elapsed().as_micros();
            let _ = std::fs::remove_file(&path);
            let mut eng = Engine::new(cfg.clone());
            let t1 = Instant::now();
            let h = eng.load(loaded, seed).expect("deploy");
            let deploy_us = t1.elapsed().as_micros();
            let x = synthetic_input(&a.graph, seed);
            let cycles = eng.infer(h, &x).expect("infer").stats.cycles;
            println!(
                "  {:<10} {:>4} {:>10} {:>10} {:>10} {:>14}",
                name,
                fmt.extension(),
                bytes,
                load_us,
                deploy_us,
                cycles
            );
            match first_cycles {
                None => first_cycles = Some(cycles),
                Some(want) => assert_eq!(
                    cycles, want,
                    "{name}: binary-loaded first response drifted from the JSON-loaded twin"
                ),
            }
        }
        let (json_b, bin_b) = (sizes[0], sizes[1]);
        let ratio = json_b as f64 / bin_b.max(1) as f64;
        assert!(
            bin_b * 5 <= json_b,
            "{name}: envelope is only {ratio:.2}x smaller than JSON \
             ({bin_b} vs {json_b} bytes; gate: >= 5x)"
        );
        println!("  cold-start gate OK: {name} envelope {ratio:.1}x smaller, cycle-identical");
    }
    println!("serve bench OK: cold-start gates passed (size >= 5x, no cycle drift)");
}
