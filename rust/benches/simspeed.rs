//! Bench — simulated-cycles-per-wall-second of the event-driven core
//! versus the per-cycle reference loop on AlexNet end-to-end (FC
//! excluded, as Table 2), plus the CI regression gate against the
//! checked-in baseline (ci/simspeed_baseline.json).
//!
//! The two cores must also report bit-identical cycle counts — this
//! bench doubles as a coarse differential check on the full model.

use std::time::Instant;

use snowflake::arch::SnowflakeConfig;
use snowflake::compiler::{deploy, CompileOptions, Compiler};
use snowflake::model::weights::{synthetic_input, Weights};
use snowflake::model::zoo;
use snowflake::sim::CoreMode;
use snowflake::util::json::Json;
use snowflake::model::graph::Graph;

/// Build through the `Compiler` front door; these tests only need the
/// compiled model, not the full artifact.
fn compile(
    g: &Graph,
    cfg: &SnowflakeConfig,
    opts: &CompileOptions,
) -> Result<snowflake::compiler::CompiledModel, snowflake::compiler::CompileError> {
    Compiler::new(cfg.clone()).options(opts.clone()).compile(g)
}

fn measure(core: CoreMode, cfg: &SnowflakeConfig) -> (u64, f64) {
    let g = zoo::alexnet_owt();
    let opts = CompileOptions { skip_fc: true, ..Default::default() };
    let compiled = compile(&g, cfg, &opts).expect("compile alexnet");
    let w = Weights::init(&g, 42);
    let x = synthetic_input(&g, 42);
    let mut m = deploy::make_machine_with(&compiled, &g, &w, &x, cfg.clone());
    m.core = core;
    let t0 = Instant::now();
    let stats = m.run().expect("simulate alexnet");
    let wall = t0.elapsed().as_secs_f64();
    (stats.cycles, wall)
}

fn baseline_cycles_per_sec() -> Option<f64> {
    let path = std::env::var("SIMSPEED_BASELINE").unwrap_or_else(|_| {
        format!("{}/../ci/simspeed_baseline.json", env!("CARGO_MANIFEST_DIR"))
    });
    let text = std::fs::read_to_string(&path).ok()?;
    let json = Json::parse(&text).ok()?;
    json.get("cycles_per_sec").as_f64()
}

fn main() {
    let cfg = SnowflakeConfig::default();

    let (cycles_event, wall_event) = measure(CoreMode::EventDriven, &cfg);
    let (cycles_ref, wall_ref) = measure(CoreMode::PerCycle, &cfg);
    assert_eq!(
        cycles_event, cycles_ref,
        "event-driven and per-cycle cores disagree on AlexNet cycles"
    );

    let cps_event = cycles_event as f64 / wall_event.max(1e-9);
    let cps_ref = cycles_ref as f64 / wall_ref.max(1e-9);
    let speedup = cps_event / cps_ref;
    println!("simspeed: AlexNet end-to-end, {cycles_event} simulated cycles");
    println!("  per-cycle core: {:>8.2}s wall  {:>8.2}M cycles/s", wall_ref, cps_ref / 1e6);
    println!("  event core:     {:>8.2}s wall  {:>8.2}M cycles/s", wall_event, cps_event / 1e6);
    println!("  speedup: {speedup:.1}x simulated-cycles-per-wall-second");

    // ISSUE 1 acceptance: >= 10x on AlexNet end-to-end. SIMSPEED_LAX
    // relaxes to a 3x floor for noisy/shared hosts.
    let floor = if std::env::var("SIMSPEED_LAX").is_ok() { 3.0 } else { 10.0 };
    assert!(
        speedup >= floor,
        "event core speedup {speedup:.2}x below the {floor}x floor"
    );

    // CI regression gate: fail if absolute event-core throughput fell
    // more than 2x below the checked-in baseline.
    match baseline_cycles_per_sec() {
        Some(base) => {
            println!(
                "  baseline: {:.2}M cycles/s (gate at {:.2}M)",
                base / 1e6,
                base / 2e6
            );
            if cps_event < base / 2.0 {
                eprintln!(
                    "REGRESSION: event core at {:.2}M cycles/s, more than 2x below the \
                     {:.2}M baseline",
                    cps_event / 1e6,
                    base / 1e6
                );
                std::process::exit(1);
            }
        }
        None => println!("  (no baseline file found; regression gate skipped)"),
    }
}
