//! Bench E2/E7 — regenerates Table 2: execution time and off-chip
//! bandwidth for AlexNetOWT, ResNet18 and ResNet50 (FC excluded, as in
//! the paper).
//!
//! Pass `--fast` via `cargo bench --bench table2 -- --fast` to skip
//! ResNet50.

use snowflake::arch::SnowflakeConfig;
use snowflake::coordinator::report;
use snowflake::util::bench::Bencher;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let cfg = SnowflakeConfig::default();
    let models: &[&str] =
        if fast { &["alexnet", "resnet18"] } else { &["alexnet", "resnet18", "resnet50"] };
    let rows = report::table2(&cfg, models, 42);
    report::print_table2(&rows);

    println!("\npaper: AlexNetOWT 10.68 ms / 1.22 GB/s; ResNet18 46.77 / 2.25; ResNet50 218.61 / 1.87");
    // Shape assertions: ordering of models by time and by bandwidth.
    let t = |name: &str| rows.iter().find(|r| r.model.contains(name)).map(|r| r.exec_ms);
    if let (Some(a), Some(r18)) = (t("alexnet"), t("resnet18")) {
        assert!(a < r18, "AlexNet must be faster than ResNet18");
        let bw = |name: &str| rows.iter().find(|r| r.model.contains(name)).unwrap().bw_gbs;
        assert!(bw("resnet18") > bw("alexnet"), "ResNet18 needs more bandwidth");
    }
    if let (Some(r18), Some(r50)) = (t("resnet18"), t("resnet50")) {
        // Paper: 4.7x. Our 1x1 conv streams avoid the VMOV bookkeeping
        // stalls the paper reports (§5.2), landing nearer the 2.3x MAC
        // ratio of the workloads.
        assert!(r50 > 2.0 * r18, "ResNet50 must be ≳2x ResNet18");
    }

    // Host-side simulation throughput for the smallest model.
    let b = Bencher::quick();
    b.run("table2/alexnet-sim", || {
        let _ = report::table2(&cfg, &["alexnet"], 42);
    });
}
