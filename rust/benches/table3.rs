//! Bench E3 — regenerates Table 3: execution speedup versus measured
//! load imbalance on the CONV 1×1 1024→2048 stride-2 layer, across the
//! balance policies of §6.3.

use snowflake::arch::SnowflakeConfig;
use snowflake::coordinator::report;
use snowflake::util::bench::Bencher;

fn main() {
    let cfg = SnowflakeConfig::default();
    let rows = report::table3(&cfg, 42);
    report::print_table3(&rows);

    println!("\npaper: imbalance 5..102% keeps speedup ~1.64-1.66x; 114% -> 1.297x; 132% -> 1.0x");
    // Shape: best balance beats the worst case, monotone-ish trend.
    let best = rows.iter().map(|r| r.speedup).fold(0.0f64, f64::max);
    let worst_imb = rows.iter().map(|r| r.imbalance_pct).fold(0.0f64, f64::max);
    assert!(best > 1.1, "fine balance must give >1.1x over the worst ({best})");
    assert!(worst_imb > 50.0, "the degenerate policies must show heavy imbalance");

    let b = Bencher::quick();
    b.run("table3/sweep", || {
        let _ = report::table3(&cfg, 42);
    });
}
