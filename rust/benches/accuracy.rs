//! Bench E5 — regenerates the §5.3 quantization profile: fp32 vs Q8.8
//! vs Q5.11. The paper reports ImageNet top-5 of 89 / 84 / 88 %; our
//! substitution measures top-1/top-5 *agreement* with fp32 on a random
//! CNN (DESIGN.md §Substitutions) plus output RMS error, reproducing
//! the ordering fp32 > Q5.11 > Q8.8.

use snowflake::coordinator::report;
use snowflake::fixed::{Q5_11, Q8_8};
use snowflake::util::bench::Bencher;

fn main() {
    let rows = report::accuracy(48, 7);
    report::print_accuracy(&rows);

    let rms88 = report::quantization_rms(Q8_8, 7);
    let rms511 = report::quantization_rms(Q5_11, 7);
    println!("\noutput RMS error vs fp32: Q8.8 {rms88:.5}  Q5.11 {rms511:.5}");
    println!("paper (ImageNet top-5): float 89%, Q5.11 88%, Q8.8 84%");

    let q511 = rows.iter().find(|r| r.format == "Q5.11").unwrap();
    let q88 = rows.iter().find(|r| r.format == "Q8.8").unwrap();
    assert!(q511.top5_agree >= q88.top5_agree, "Q5.11 must agree at least as well");
    assert!(rms511 < rms88, "Q5.11 must have lower RMS error");

    let b = Bencher::quick();
    b.run("accuracy/16-inputs", || {
        let _ = report::accuracy(16, 7);
    });
}
