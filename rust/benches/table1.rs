//! Bench E1/E6 — regenerates Table 1: hand-optimized vs auto-generated
//! instruction streams on the four AlexNet conv layers, plus host-side
//! timing of the simulation itself.

use snowflake::arch::SnowflakeConfig;
use snowflake::coordinator::report;
use snowflake::util::bench::Bencher;

fn main() {
    let cfg = SnowflakeConfig::default();
    let rows = report::table1(&cfg, 42);
    report::print_table1(&rows);

    // Paper-shape checks (loudly, so regressions surface in CI logs).
    for r in &rows {
        let ratio = r.auto_ms / r.hand_ms;
        println!(
            "  {}: auto/hand time ratio {:.4} (paper: ~1.00x), instr delta {}",
            r.layer,
            ratio,
            r.auto_instrs as i64 - r.hand_instrs as i64
        );
        assert!(ratio < 1.15, "auto should be within 15% of hand ({ratio})");
        assert!(r.auto_instrs >= r.hand_instrs);
    }

    // Host-side cost of one hand/auto pair (compile + simulate).
    let b = Bencher::quick();
    b.run("table1/full-regeneration", || {
        let _ = report::table1(&cfg, 42);
    });
}
