//! Bench + CI gate for cost-model-driven schedule tuning.
//!
//! Four checks, on AlexNetOWT and ResNet18 end-to-end (FC excluded, as
//! Table 2) plus the banked-rotation scenario:
//!
//! 1. **Prediction error**: the analytical model's predicted cycles per
//!    conv layer must stay within `cost::MODEL_ERROR_BOUND` of the
//!    event core (either direction), layer by layer.
//! 2. **Tuning quality**: measured-tuned schedules must never be slower
//!    than the seed heuristic, the analytical search *or* the best
//!    forced-Kloop configuration (the tuner seeds its incumbent with
//!    all three, so a violation is a code bug).
//! 3. **Absolute regression gate**: when `ci/schedule_baseline.json`
//!    carries blessed cycle counts (deterministic; regenerate with
//!    `repro bless-baselines`), tuned cycles exceeding the baseline
//!    fail the run.
//! 4. **Rotation single-pass kernels**: on the bandwidth-starved
//!    AlexNet-conv1 scenario the tuner must pick the banked-rotation
//!    skeleton, the simulated kernel-stream DRAM reads must equal
//!    exactly one pass (`weights × word_bytes`), and the layer must
//!    beat its forced-Kloop compile on total cycles.

use snowflake::arch::SnowflakeConfig;
use snowflake::compiler::cost::MODEL_ERROR_BOUND;
use snowflake::compiler::decide::OpPlan;
use snowflake::compiler::{CompileOptions, LoopOrder, TuneMode};
use snowflake::coordinator::{driver, report};
use snowflake::model::graph::Graph;
use snowflake::model::layer::{LayerKind, Shape};
use snowflake::util::json::Json;

/// The blessed baseline: distinguish "absent" (gate legitimately
/// skipped) from "unparsable" (must fail loudly, not disarm the gate).
enum Baseline {
    Missing,
    Corrupt(String),
    Loaded(Json),
}

fn baseline() -> Baseline {
    let path = std::env::var("SCHEDULE_BASELINE").unwrap_or_else(|_| {
        format!("{}/../ci/schedule_baseline.json", env!("CARGO_MANIFEST_DIR"))
    });
    match std::fs::read_to_string(&path) {
        Err(_) => Baseline::Missing,
        Ok(text) => match Json::parse(&text) {
            Ok(j) => Baseline::Loaded(j),
            Err(e) => Baseline::Corrupt(format!("{path}: {e}")),
        },
    }
}

/// The banked-rotation acceptance scenario (ISSUE 5), shared with
/// `tests/rotation.rs`: AlexNet conv1 (3 forced map tiles > 2 MBuf
/// banks) on a bandwidth-starved board variant whose WBuf holds every
/// kernel group in one region. The tuned schedule must pick the
/// rotation skeleton, kernel DRAM reads must collapse to a single pass,
/// and the layer must beat the forced-Kloop compile on cycles.
fn rotation_gate() -> usize {
    let cfg = SnowflakeConfig {
        wbuf_bytes: 64 * 1024,
        axi_bytes_per_cycle: 1.4,
        ..SnowflakeConfig::default()
    };
    let mut g = Graph::new("alexnet_conv1_rot", Shape::new(3, 224, 224));
    g.push_seq(
        LayerKind::Conv { in_ch: 3, out_ch: 64, kh: 11, kw: 11, stride: 4, pad: 2, relu: true },
        "conv1",
    );
    let tuned = driver::run_model(&g, &cfg, &CompileOptions::default(), 42).expect("tuned run");
    let OpPlan::Conv(d) = &tuned.compiled.plan.layers[0].decision else { unreachable!() };
    let mut failures = 0usize;
    if d.order != LoopOrder::MloopRot || d.n_tiles <= cfg.mbuf_banks {
        eprintln!(
            "ROTATION GATE: tuner chose {:?} with {} tiles (wanted MloopRot, > {} tiles)",
            d.order, d.n_tiles, cfg.mbuf_banks
        );
        failures += 1;
    }
    let single_pass = (d.k_groups * 4 * d.kernel_words * cfg.word_bytes) as u64;
    if tuned.stats.bytes_wbuf != single_pass {
        eprintln!(
            "ROTATION GATE: kernel stream read {} bytes, single pass is {single_pass}",
            tuned.stats.bytes_wbuf
        );
        failures += 1;
    }
    let kloop_opts = CompileOptions {
        force_loop_order: Some(LoopOrder::Kloop),
        tune: TuneMode::Analytical,
        ..Default::default()
    };
    let kloop = driver::run_model(&g, &cfg, &kloop_opts, 42).expect("kloop run");
    println!(
        "rotation gate: tuned (MloopRot) {} cycles / {} kernel bytes vs forced-Kloop {} cycles \
         / {} kernel bytes",
        tuned.stats.cycles, tuned.stats.bytes_wbuf, kloop.stats.cycles, kloop.stats.bytes_wbuf
    );
    if tuned.stats.cycles >= kloop.stats.cycles {
        eprintln!(
            "ROTATION GATE: rotation {} cycles not below forced-Kloop {}",
            tuned.stats.cycles, kloop.stats.cycles
        );
        failures += 1;
    }
    failures
}

fn main() {
    let cfg = SnowflakeConfig::default();
    let models = ["alexnet", "resnet18"];
    let mut failures = 0usize;

    // The baseline records the (seed, top_k) it was blessed with; the
    // gate must re-measure under the same parameters to be comparable.
    let base = baseline();
    let (seed, top_k) = match &base {
        Baseline::Loaded(j) => (
            j.get("seed").as_i64().unwrap_or(42) as u64,
            j.get("top_k").as_i64().unwrap_or(2) as usize,
        ),
        _ => (42, 2),
    };

    // ---- 1. per-layer prediction error -------------------------------
    for m in &models {
        let rows = report::prediction_error(&cfg, m, seed);
        report::print_prediction_error(m, &rows);
        for r in &rows {
            if r.ratio > MODEL_ERROR_BOUND || r.ratio < 1.0 / MODEL_ERROR_BOUND {
                eprintln!(
                    "MODEL ERROR: {m}/{}: predicted {} vs measured {} (ratio {:.2}) outside \
                     the {MODEL_ERROR_BOUND:.1}x bound",
                    r.layer, r.predicted, r.measured, r.ratio
                );
                failures += 1;
            }
        }
        println!();
    }

    // ---- 2. heuristic vs cost-model vs measured ----------------------
    // (The heuristic/cost-model sweep legs intentionally duplicate the
    // baselines tune_measured simulates internally: the table rows come
    // from the standard compile path, independent of the tuner's
    // bookkeeping.)
    let t0 = std::time::Instant::now();
    let rows = report::schedule_quality(&cfg, &models, seed, top_k);
    report::print_schedule_quality(&rows);
    println!("(schedule-quality sweep + measured tuning in {:?})", t0.elapsed());

    let cycles_of = |model: &str, mode: &str| {
        rows.iter()
            .find(|r| r.model == model && r.mode == mode)
            .unwrap_or_else(|| panic!("missing {model}/{mode} row"))
            .cycles
    };
    for m in &models {
        let h = cycles_of(m, "heuristic");
        let t = cycles_of(m, "measured");
        let a = cycles_of(m, "cost-model");
        let fk = cycles_of(m, "forced-kloop");
        println!(
            "{m}: heuristic {h} | cost-model {a} ({:+.2}%) | forced-kloop {fk} ({:+.2}%) | \
             measured {t} ({:+.2}%)",
            (a as f64 / h as f64 - 1.0) * 100.0,
            (fk as f64 / h as f64 - 1.0) * 100.0,
            (t as f64 / h as f64 - 1.0) * 100.0
        );
        // The tuner seeds its incumbent with all three baselines, so
        // tuned must be <= min(heuristic, analytical, forced-Kloop).
        let floor = h.min(a).min(fk);
        if t > floor {
            eprintln!(
                "TUNING REGRESSION: {m} measured-tuned {t} cycles slower than the best \
                 baseline {floor} (heuristic {h} / cost-model {a} / forced-kloop {fk}) — \
                 the tuner must never lose to a configuration it trials"
            );
            failures += 1;
        }
    }

    // ---- 2b. banked rotation reads the kernel stream exactly once ----
    failures += rotation_gate();

    // ---- 3. absolute gate vs the blessed baseline --------------------
    match base {
        Baseline::Corrupt(e) => {
            eprintln!("BASELINE UNREADABLE: {e} — fix or re-bless ci/schedule_baseline.json");
            failures += 1;
        }
        Baseline::Loaded(json) => {
            let mut gated = 0usize;
            for m in &models {
                let base = json.get("models").get(m).get("tuned_cycles").as_i64();
                match base {
                    Some(base) => {
                        gated += 1;
                        let t = cycles_of(m, "measured");
                        if t > base as u64 {
                            eprintln!(
                                "SCHEDULE REGRESSION: {m} tuned {t} cycles exceeds the blessed \
                                 baseline {base} (ci/schedule_baseline.json)"
                            );
                            failures += 1;
                        } else if t < base as u64 {
                            println!(
                                "{m}: tuned {t} beats the blessed baseline {base} — consider \
                                 `repro bless-baselines`"
                            );
                        }
                    }
                    None => println!("{m}: no blessed entry; absolute gate skipped"),
                }
            }
            if gated == 0 {
                println!(
                    "(baseline has no model entries yet; run `repro bless-baselines` to arm \
                     the absolute gate — the relative tuned<=heuristic gate is always on)"
                );
            }
        }
        Baseline::Missing => println!("(no ci/schedule_baseline.json found; absolute gate skipped)"),
    }

    if failures > 0 {
        eprintln!("{failures} tuning gate failure(s)");
        std::process::exit(1);
    }
    println!("tuning gates passed");
}
